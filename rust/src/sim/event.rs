//! Generic totally-ordered event queue.
//!
//! Each scheduler defines its own event payload type `E`; the queue
//! orders by `(time, seq)` where `seq` is an insertion counter, so
//! simulations are fully deterministic regardless of payload.
//!
//! The implementation is a **bucketed calendar queue** (§Perf iteration
//! 5): future events are dropped unsorted into fixed-width time buckets
//! and each bucket is sorted only when the clock reaches it
//! (sort-on-drain). Pushing is O(1) amortized instead of the
//! `BinaryHeap`'s O(log n), pops drain a small contiguous buffer, and
//! the whole structure is cache-friendly because one bucket at a time is
//! hot. The total order is *exactly* the heap's `(time, seq)` order —
//! [`HeapEventQueue`] below is the retained reference oracle, and the
//! randomized tests at the bottom drive both implementations through
//! identical push/pop interleavings and demand identical output.
//!
//! Layout: `cur` holds the bucket currently being drained, sorted
//! descending so `pop` is a `Vec::pop`; `buckets[i]` covers
//! `[base + i·width, base + (i+1)·width)`; everything at or beyond the
//! window lands in `overflow` and is redistributed (with a freshly
//! fitted `width`) once the window drains. Pushes into the draining
//! bucket's own interval go to `near`, a small staging min-heap merged
//! at pop time (comparing against `cur`'s back) — O(log s) in the
//! number of *staged* events, with none of the memmove cliffs a sorted
//! `Vec::insert` would hit on same-timestamp bursts. FIFO tie-breaking
//! holds throughout because `seq` grows monotonically and is part of
//! every comparison.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use super::time::SimTime;

/// Number of buckets in the calendar window.
const N_BUCKETS: usize = 256;

/// Target events per bucket when fitting `width` at a rebase. The
/// window is sized to the *near segment* of the overflow (about
/// `N_BUCKETS * TARGET_PER_BUCKET` events), not its full span —
/// otherwise one far-future outlier (a 5 s heartbeat against sub-ms
/// message delays) would stretch buckets so wide that nearly every
/// push lands in the draining interval and degenerates into the
/// staging heap. Events past the fitted window stay in `overflow` for
/// a later rebase.
const TARGET_PER_BUCKET: usize = 32;

/// Cap on recycled bucket vectors kept for reuse.
const SPARE_CAP: usize = N_BUCKETS + 4;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    ev: E,
}

impl<E> Entry<E> {
    #[inline]
    fn key(&self) -> (u64, u64) {
        (self.time.as_micros(), self.seq)
    }
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // inverted: BinaryHeap is a max-heap, we want earliest-first
        other.key().cmp(&self.key())
    }
}

/// Earliest-first event queue with deterministic FIFO tie-breaking.
pub struct EventQueue<E> {
    /// The bucket being drained, sorted descending by `(time, seq)`
    /// (pop takes from the back). All entries are `< base`.
    cur: Vec<Entry<E>>,
    /// Staging heap (earliest-first) for events pushed into the
    /// draining bucket's own interval (`>= now`, `< base`) after the
    /// drain began; merged with `cur` at pop time.
    near: BinaryHeap<Entry<E>>,
    /// `buckets[i]` covers `[base + i·width, base + (i+1)·width)`,
    /// unsorted.
    buckets: VecDeque<Vec<Entry<E>>>,
    /// Start (µs) of `buckets[0]`.
    base: u64,
    /// Bucket width in microseconds (>= 1).
    width: u64,
    /// Entries at or beyond the bucketed window, redistributed on demand.
    overflow: Vec<Entry<E>>,
    /// Recycled empty bucket vectors (keeps steady-state allocation-free).
    spare: Vec<Vec<Entry<E>>>,
    seq: u64,
    now: SimTime,
    len: usize,
    pushed: u64,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            cur: Vec::new(),
            near: BinaryHeap::new(),
            buckets: VecDeque::new(),
            base: 0,
            width: 1,
            overflow: Vec::new(),
            spare: Vec::new(),
            seq: 0,
            now: SimTime::ZERO,
            len: 0,
            pushed: 0,
            popped: 0,
        }
    }

    /// Schedule `ev` at absolute time `at`. Must not be in the past.
    pub fn push(&mut self, at: SimTime, ev: E) {
        debug_assert!(at >= self.now, "event scheduled in the past");
        let e = Entry {
            time: at,
            seq: self.seq,
            ev,
        };
        self.seq += 1;
        self.pushed += 1;
        self.len += 1;
        let t = at.as_micros();
        if t < self.base {
            // Inside the draining bucket's interval: stage in the side
            // heap (merged at pop). Monotonic `seq` keeps FIFO ties.
            self.near.push(e);
            return;
        }
        let idx = ((t - self.base) / self.width) as usize;
        if idx < self.buckets.len() {
            self.buckets[idx].push(e);
        } else {
            self.overflow.push(e);
        }
    }

    /// Schedule `ev` after a delay from the current time.
    pub fn push_after(&mut self, delay: SimTime, ev: E) {
        self.push(self.now + delay, ev);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        if self.cur.is_empty() && self.near.is_empty() {
            self.refill();
        }
        // both `cur` and `near` hold only events `< base`, so whichever
        // of the two heads is earlier is the global minimum
        let take_near = match (self.cur.last(), self.near.peek()) {
            (Some(c), Some(n)) => n.key() < c.key(),
            (None, Some(_)) => true,
            _ => false,
        };
        let e = if take_near {
            self.near.pop()?
        } else {
            self.cur.pop()?
        };
        debug_assert!(e.time >= self.now);
        self.now = e.time;
        self.popped += 1;
        self.len -= 1;
        Some((e.time, e.ev))
    }

    /// Advance the window until `cur` holds the next non-empty bucket
    /// (sorted), or the queue is confirmed empty.
    ///
    /// The window *shrinks* as it drains (its end stays where the last
    /// rebase put it): every bucketed event is therefore strictly
    /// earlier than every overflow event, so draining buckets before
    /// ever consulting the overflow is order-correct.
    fn refill(&mut self) {
        // advancing `base` is only sound once everything before it has
        // drained — both the sorted buffer and the staging heap
        debug_assert!(self.cur.is_empty() && self.near.is_empty());
        loop {
            if let Some(mut b) = self.buckets.pop_front() {
                self.base += self.width;
                if b.is_empty() {
                    self.recycle(b);
                    continue;
                }
                b.sort_unstable_by(|a, c| c.key().cmp(&a.key())); // descending
                std::mem::swap(&mut self.cur, &mut b);
                self.recycle(b);
                return;
            }
            if self.overflow.is_empty() {
                return; // queue fully drained
            }
            self.rebase();
        }
    }

    /// Rebuild the bucket window over the pending overflow, fitting the
    /// bucket width to the overflow's *near segment* (the next
    /// `N_BUCKETS * TARGET_PER_BUCKET` events by `(time, seq)`), so
    /// bucket granularity tracks local event density rather than the
    /// full horizon. Events beyond the fitted window stay in `overflow`
    /// — the bucketed-before-overflow drain order keeps that correct.
    fn rebase(&mut self) {
        debug_assert!(!self.overflow.is_empty());
        let mut lo = u64::MAX;
        for e in &self.overflow {
            lo = lo.min(e.time.as_micros());
        }
        let q = (N_BUCKETS * TARGET_PER_BUCKET).min(self.overflow.len()) - 1;
        let t_q = if q + 1 < self.overflow.len() {
            let (_, e, _) = self.overflow.select_nth_unstable_by_key(q, |e| e.key());
            e.time.as_micros()
        } else {
            self.overflow
                .iter()
                .map(|e| e.time.as_micros())
                .max()
                .unwrap_or(lo)
        };
        self.base = lo;
        self.width = ((t_q - lo) / N_BUCKETS as u64 + 1).max(1);
        while self.buckets.len() < N_BUCKETS {
            self.buckets.push_back(self.spare.pop().unwrap_or_default());
        }
        let end = self
            .base
            .saturating_add(self.width.saturating_mul(self.buckets.len() as u64));
        let mut keep = Vec::new();
        for e in self.overflow.drain(..) {
            let t = e.time.as_micros();
            if t < end {
                let idx = ((t - self.base) / self.width) as usize;
                self.buckets[idx].push(e);
            } else {
                keep.push(e);
            }
        }
        self.overflow = keep;
    }

    fn recycle(&mut self, b: Vec<Entry<E>>) {
        debug_assert!(b.is_empty());
        if self.spare.len() < SPARE_CAP {
            self.spare.push(b);
        }
    }

    /// Timestamp of the earliest pending event without popping it, or
    /// `None` when the queue is drained. `&mut` because peeking may have
    /// to advance the calendar window (sort the next bucket), exactly as
    /// [`pop`](Self::pop) would; the observable state (order, clock,
    /// counters) is unchanged. The sharded driver uses this to decide
    /// whether a shard's head event falls inside the current epoch.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        if self.cur.is_empty() && self.near.is_empty() {
            self.refill();
        }
        match (self.cur.last(), self.near.peek()) {
            (Some(c), Some(n)) => Some(if n.key() < c.key() { n.time } else { c.time }),
            (Some(c), None) => Some(c.time),
            (None, Some(n)) => Some(n.time),
            (None, None) => None,
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Total events processed so far (for throughput metrics).
    pub fn popped(&self) -> u64 {
        self.popped
    }
}

/// The pre-iteration-5 `BinaryHeap` implementation, retained verbatim as
/// the reference oracle for the calendar queue: same API, same
/// `(time, seq)` total order. The randomized equivalence tests below and
/// the `queue/*` benches drive it; production code uses [`EventQueue`].
pub mod oracle {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    use crate::sim::time::SimTime;

    struct Entry<E> {
        time: SimTime,
        seq: u64,
        ev: E,
    }

    impl<E> PartialEq for Entry<E> {
        fn eq(&self, other: &Self) -> bool {
            self.time == other.time && self.seq == other.seq
        }
    }
    impl<E> Eq for Entry<E> {}
    impl<E> PartialOrd for Entry<E> {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl<E> Ord for Entry<E> {
        fn cmp(&self, other: &Self) -> Ordering {
            // BinaryHeap is a max-heap; invert for earliest-first.
            other
                .time
                .cmp(&self.time)
                .then_with(|| other.seq.cmp(&self.seq))
        }
    }

    /// Heap-backed earliest-first queue (the reference oracle).
    pub struct HeapEventQueue<E> {
        heap: BinaryHeap<Entry<E>>,
        seq: u64,
        now: SimTime,
        pushed: u64,
        popped: u64,
    }

    impl<E> Default for HeapEventQueue<E> {
        fn default() -> Self {
            Self::new()
        }
    }

    impl<E> HeapEventQueue<E> {
        pub fn new() -> Self {
            HeapEventQueue {
                heap: BinaryHeap::new(),
                seq: 0,
                now: SimTime::ZERO,
                pushed: 0,
                popped: 0,
            }
        }

        pub fn push(&mut self, at: SimTime, ev: E) {
            debug_assert!(at >= self.now, "event scheduled in the past");
            self.heap.push(Entry {
                time: at,
                seq: self.seq,
                ev,
            });
            self.seq += 1;
            self.pushed += 1;
        }

        pub fn push_after(&mut self, delay: SimTime, ev: E) {
            self.push(self.now + delay, ev);
        }

        pub fn pop(&mut self) -> Option<(SimTime, E)> {
            self.heap.pop().map(|e| {
                debug_assert!(e.time >= self.now);
                self.now = e.time;
                self.popped += 1;
                (e.time, e.ev)
            })
        }

        pub fn now(&self) -> SimTime {
            self.now
        }

        pub fn is_empty(&self) -> bool {
            self.heap.is_empty()
        }

        pub fn len(&self) -> usize {
            self.heap.len()
        }

        pub fn popped(&self) -> u64 {
            self.popped
        }
    }
}

pub use oracle::HeapEventQueue;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_micros(30), "c");
        q.push(SimTime::from_micros(10), "a");
        q.push(SimTime::from_micros(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        let t = SimTime::from_micros(5);
        for i in 0..10 {
            q.push(t, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_micros(100), ());
        q.push(SimTime::from_micros(50), ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.now(), t1);
        q.push_after(SimTime::from_micros(10), ());
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, SimTime::from_micros(60));
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, SimTime::from_micros(100));
    }

    #[test]
    fn counters() {
        let mut q = EventQueue::new();
        for i in 0..5u32 {
            q.push(SimTime::from_micros(i as u64), i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.popped(), 5);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn push_into_draining_bucket_keeps_order() {
        // Force a drained bucket, then push events landing inside its
        // interval (>= now, < base): they must interleave correctly.
        let mut q = EventQueue::new();
        for i in 0..100u64 {
            q.push(SimTime::from_micros(i * 3), i);
        }
        let (_, first) = q.pop().unwrap();
        assert_eq!(first, 0);
        // now = 0; push events just ahead of the clock
        q.push(SimTime::from_micros(1), 1000);
        q.push(SimTime::from_micros(2), 1001);
        q.push(SimTime::from_micros(3), 1002); // ties with seq-earlier event at t=3
        let mut got = Vec::new();
        for _ in 0..4 {
            got.push(q.pop().unwrap());
        }
        assert_eq!(got[0], (SimTime::from_micros(1), 1000));
        assert_eq!(got[1], (SimTime::from_micros(2), 1001));
        // FIFO tie at t=3: the original event (earlier seq) first
        assert_eq!(got[2], (SimTime::from_micros(3), 1));
        assert_eq!(got[3], (SimTime::from_micros(3), 1002));
    }

    #[test]
    fn distant_jumps_rebase_correctly() {
        // sparse far-future events force repeated rebasing
        let mut q = EventQueue::new();
        let times = [0u64, 5, 1_000_000, 1_000_001, 500_000_000, 500_000_000];
        for (i, &t) in times.iter().enumerate() {
            q.push(SimTime::from_micros(t), i);
        }
        let got: Vec<(u64, usize)> = std::iter::from_fn(|| q.pop())
            .map(|(t, e)| (t.as_micros(), e))
            .collect();
        assert_eq!(
            got,
            vec![
                (0, 0),
                (5, 1),
                (1_000_000, 2),
                (1_000_001, 3),
                (500_000_000, 4),
                (500_000_000, 5),
            ]
        );
    }

    /// Drive the calendar queue and the heap oracle through identical
    /// randomized push/pop interleavings: every pop must return the same
    /// `(time, payload)` pair, so the total orders are identical
    /// (payloads uniquely tag events, which also pins FIFO ties).
    #[test]
    fn matches_heap_oracle_on_random_interleavings() {
        for seed in 0..25u64 {
            let mut rng = Rng::new(seed);
            let mut q: EventQueue<u32> = EventQueue::new();
            let mut o: HeapEventQueue<u32> = HeapEventQueue::new();
            let mut tag = 0u32;
            for step in 0..4_000 {
                let push = q.is_empty() || rng.below(100) < 55;
                if push {
                    // mixed horizons: bursts at now, near-future, and
                    // far-future jumps stress every code path
                    let d = match rng.below(5) {
                        0 => 0,
                        1 => rng.below(8) as u64,
                        2 => rng.below(500) as u64,
                        3 => rng.below(50_000) as u64,
                        _ => 1_000_000 + rng.below(10_000_000) as u64,
                    };
                    let at = SimTime::from_micros(q.now().as_micros() + d);
                    q.push(at, tag);
                    o.push(at, tag);
                    tag += 1;
                } else {
                    let a = q.pop();
                    let b = o.pop();
                    assert_eq!(
                        a.is_some(),
                        b.is_some(),
                        "seed {seed} step {step}: emptiness diverged"
                    );
                    if let (Some((ta, ea)), Some((tb, eb))) = (a, b) {
                        assert_eq!(
                            (ta, ea),
                            (tb, eb),
                            "seed {seed} step {step}: pop order diverged"
                        );
                    }
                    assert_eq!(q.now(), o.now(), "seed {seed} step {step}: clock diverged");
                }
                assert_eq!(q.len(), o.len(), "seed {seed} step {step}: length diverged");
            }
            // full drain must agree too
            loop {
                let (a, b) = (q.pop(), o.pop());
                assert_eq!(a, b, "seed {seed}: drain diverged");
                if a.is_none() {
                    break;
                }
            }
            assert_eq!(q.popped(), o.popped(), "seed {seed}: popped count diverged");
        }
    }
}
