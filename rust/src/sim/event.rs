//! Generic totally-ordered event queue.
//!
//! Each scheduler defines its own event payload type `E`; the queue
//! orders by `(time, seq)` where `seq` is an insertion counter, so
//! simulations are fully deterministic regardless of payload.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::SimTime;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with deterministic FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
    pushed: u64,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: SimTime::ZERO,
            pushed: 0,
            popped: 0,
        }
    }

    /// Schedule `ev` at absolute time `at`. Must not be in the past.
    pub fn push(&mut self, at: SimTime, ev: E) {
        debug_assert!(at >= self.now, "event scheduled in the past");
        self.heap.push(Entry {
            time: at,
            seq: self.seq,
            ev,
        });
        self.seq += 1;
        self.pushed += 1;
    }

    /// Schedule `ev` after a delay from the current time.
    pub fn push_after(&mut self, delay: SimTime, ev: E) {
        self.push(self.now + delay, ev);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time >= self.now);
            self.now = e.time;
            self.popped += 1;
            (e.time, e.ev)
        })
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Total events processed so far (for throughput metrics).
    pub fn popped(&self) -> u64 {
        self.popped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_micros(30), "c");
        q.push(SimTime::from_micros(10), "a");
        q.push(SimTime::from_micros(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        let t = SimTime::from_micros(5);
        for i in 0..10 {
            q.push(t, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_micros(100), ());
        q.push(SimTime::from_micros(50), ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.now(), t1);
        q.push_after(SimTime::from_micros(10), ());
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, SimTime::from_micros(60));
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, SimTime::from_micros(100));
    }

    #[test]
    fn counters() {
        let mut q = EventQueue::new();
        for i in 0..5u32 {
            q.push(SimTime::from_micros(i as u64), i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.popped(), 5);
        assert!(q.is_empty());
    }
}
