//! Shared simulation driver: the one event loop all schedulers run on.
//!
//! Before this layer existed every scheduler hand-rolled the same loop:
//! push trace arrivals, pop events, thread `(queue, rng, tracker, out)`
//! through every handler, then merge counters into a [`RunOutcome`]. The
//! driver owns that plumbing; a scheduler only supplies its event payload
//! type and the per-event logic via the [`Scheduler`] trait.
//!
//! Determinism contract: the driver injects one [`DriverEv::Arrival`] per
//! trace job *before* calling [`Scheduler::init`], so arrival events
//! occupy the same `(time, seq)` slots the hand-rolled loops gave them,
//! and the single [`Rng`] (seeded from `SimParams::seed`) is handed to
//! handlers through [`SimCtx`] in event order. A port of a hand-rolled
//! loop that draws randomness and pushes events in the same order is
//! therefore *bit-identical* to its pre-driver behavior — the golden
//! tests in `tests/driver_invariants.rs` pin this down.

use std::any::{Any, TypeId};
use std::time::Instant;

use crate::config::SimParams;
use crate::metrics::{RunOutcome, ShardFallback};
use crate::obs::flight::{self, Actor, EvKind, FlightRecorder, NONE};
use crate::sched::common::JobTracker;
use crate::sim::event::EventQueue;
use crate::sim::net::NetModel;
use crate::sim::time::SimTime;
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Per-pool cap on retained buffers of one element type.
const POOL_CAP: usize = 64;

/// Recycled `Vec<T>` buffers, keyed by element type.
///
/// Message payloads (`Vec<Mapping>` verification batches, `Vec<(u32,
/// u32)>` inconsistency replies, probe/duration vectors) used to be
/// malloc-per-message on the hot path. Handlers instead [`take`] a
/// cleared buffer (reusing a previous message's capacity) and [`give`]
/// it back once the payload is consumed. Pooling never touches the RNG
/// or event order, so it is behavior-neutral by construction —
/// `tests/driver_invariants.rs` pins bit-identity against
/// [`BufPools::disabled`], where `take` always allocates fresh.
///
/// [`take`]: BufPools::take
/// [`give`]: BufPools::give
pub struct BufPools {
    /// One stack of spare buffers per element type seen so far. The
    /// linear scan is over a handful of entries (one per payload type a
    /// scheduler uses), far cheaper than hashing. (`+ Send` so a pool
    /// can live on a shard's worker thread — see [`run_sharded`].)
    slots: Vec<(TypeId, Box<dyn Any + Send>)>,
    enabled: bool,
}

impl Default for BufPools {
    fn default() -> Self {
        Self::new()
    }
}

impl BufPools {
    pub fn new() -> BufPools {
        BufPools {
            slots: Vec::new(),
            enabled: true,
        }
    }

    /// A pass-through pool: `take` always allocates and `give` drops.
    /// Tests run schedulers on this to prove pooling changes nothing.
    pub fn disabled() -> BufPools {
        BufPools {
            slots: Vec::new(),
            enabled: false,
        }
    }

    /// Get a cleared buffer, reusing a recycled one when available.
    pub fn take<T: Send + 'static>(&mut self) -> Vec<T> {
        if self.enabled {
            let id = TypeId::of::<T>();
            for (tid, stack) in &mut self.slots {
                if *tid == id {
                    let stack = stack
                        .downcast_mut::<Vec<Vec<T>>>()
                        .expect("pool slot holds its keyed type");
                    return stack.pop().unwrap_or_default();
                }
            }
        }
        Vec::new()
    }

    /// Return a buffer for reuse (cleared here; contents are dropped).
    pub fn give<T: Send + 'static>(&mut self, mut v: Vec<T>) {
        if !self.enabled || v.capacity() == 0 {
            return;
        }
        v.clear();
        let id = TypeId::of::<T>();
        for (tid, stack) in &mut self.slots {
            if *tid == id {
                let stack = stack
                    .downcast_mut::<Vec<Vec<T>>>()
                    .expect("pool slot holds its keyed type");
                if stack.len() < POOL_CAP {
                    stack.push(v);
                }
                return;
            }
        }
        let stack: Vec<Vec<T>> = vec![v];
        self.slots.push((id, Box::new(stack)));
    }
}

/// Driver-level event: trace arrivals are injected by the driver itself;
/// everything else is the scheduler's own payload type.
pub enum DriverEv<E> {
    /// Job `.0` (trace index) reaches its scheduler.
    Arrival(u32),
    /// A scheduler-defined event.
    Sched(E),
}

/// Sharded-mode routing state threaded through [`SimCtx`]: a push whose
/// event homes on another shard diverts to the epoch's exchange log
/// instead of the local queue (see [`run_sharded`]). The log is
/// bucketed by destination shard at push time, so the barrier replays
/// straight per-destination runs instead of scanning a mixed log.
struct ShardRoute<'a, E> {
    my_shard: usize,
    shard_of: &'a (dyn Fn(&E) -> usize + Sync),
    /// One bucket per destination shard (the self-bucket stays empty).
    outbox: &'a mut [Vec<(SimTime, E)>],
}

/// Everything a scheduler may touch during one event: the clock, the
/// event queue (wrapped so schedulers can only push their own payloads),
/// the run's RNG and network model, the trace, completion bookkeeping,
/// and the run-wide counters.
pub struct SimCtx<'a, E> {
    q: &'a mut EventQueue<DriverEv<E>>,
    /// The run's single deterministic RNG (draw order = event order).
    pub rng: &'a mut Rng,
    net: &'a NetModel,
    tracker: &'a mut JobTracker,
    /// The workload being scheduled (read-only).
    pub trace: &'a Trace,
    /// Run-wide counters; merged into the final [`RunOutcome`].
    pub out: &'a mut RunOutcome,
    /// Recycled message-payload buffers (see [`BufPools`]).
    pub pool: &'a mut BufPools,
    /// `Some` only under [`run_sharded`]: cross-shard pushes divert here.
    route: Option<ShardRoute<'a, E>>,
    /// `Some` only under [`run_sharded`]: the epoch-start snapshot of
    /// global completion, identical across execution modes (a shard's
    /// local tracker only sees its own jobs, so it cannot answer
    /// [`all_done`](Self::all_done) itself).
    done_override: Option<bool>,
    /// Flight recorder (lane-private under sharded execution). Off by
    /// default; see [`SimCtx::flight`].
    rec: &'a mut FlightRecorder,
}

impl<E> SimCtx<'_, E> {
    /// Current simulated time (the timestamp of the event being handled).
    pub fn now(&self) -> SimTime {
        self.q.now()
    }

    /// Schedule `ev` at absolute time `at`. Under sharded execution an
    /// event homed on another shard goes to the exchange log instead and
    /// reaches its destination queue at the next epoch barrier.
    pub fn push(&mut self, at: SimTime, ev: E) {
        if let Some(r) = self.route.as_mut() {
            let dest = (r.shard_of)(&ev);
            if dest != r.my_shard {
                r.outbox[dest].push((at, ev));
                return;
            }
        }
        self.q.push(at, DriverEv::Sched(ev));
    }

    /// Schedule `ev` after a delay from now.
    pub fn push_after(&mut self, delay: SimTime, ev: E) {
        let at = self.q.now() + delay;
        self.push(at, ev);
    }

    /// Draw one network latency from the run's model at the current sim
    /// time (time matters only to the fault-injection `Degraded` overlay;
    /// every other model ignores it).
    pub fn net_delay(&mut self) -> SimTime {
        let now = self.q.now();
        self.net.delay_at(now, self.rng)
    }

    /// Send `ev` over the network: one latency draw, one message counted,
    /// delivery scheduled after the drawn delay.
    pub fn send(&mut self, ev: E) {
        let d = self.net_delay();
        self.out.messages += 1;
        self.push_after(d, ev);
    }

    /// Record one finished task of `job`; returns true if the job is done.
    pub fn task_done(&mut self, job: u32) -> bool {
        let now = self.q.now();
        self.tracker.task_done(self.trace, job as usize, now)
    }

    /// Record one fault-killed task of `job` that had accrued `lost`
    /// task-seconds of execution. Must be called on the lane that owns
    /// the job's completions (the same lane that will later call
    /// [`task_redispatched`](Self::task_redispatched)), so the per-job
    /// kill FIFO and [`crate::metrics::JobRecord::killed`] land on the
    /// tracker whose record survives the shard merge.
    pub fn task_killed(&mut self, job: u32, lost: SimTime) {
        let now = self.q.now();
        self.out.tasks_killed += 1;
        self.out.work_lost_s += lost.as_secs();
        self.tracker.task_killed(job as usize, now);
    }

    /// Pair a successful placement of `job` with its oldest outstanding
    /// kill, if any, recording the time-to-redispatch sample. Call at
    /// every commit point on the job's owning lane; a no-op (single
    /// predictable branch) while no kill is pending, so fault-free runs
    /// are untouched.
    pub fn task_redispatched(&mut self, job: u32) {
        let now = self.q.now();
        if let Some(s) = self.tracker.task_redispatched(job as usize, now) {
            self.out.tasks_rerun += 1;
            self.out.redispatch_s.push(s);
            let us = (s * 1e6) as u64;
            self.flight(EvKind::Redispatch, Actor::Driver(0), job, NONE, us);
        }
    }

    /// Mark `job` constraint-blocked as of now (idempotent): a placement
    /// failed purely because of the job's demand. Feeds the per-job
    /// `constraint_wait` breakdown (see [`JobTracker::constraint_block`]).
    pub fn constraint_block(&mut self, job: u32) {
        let now = self.q.now();
        self.tracker.constraint_block(job as usize, now);
    }

    /// Close `job`'s constraint-blocked interval (no-op when not blocked).
    pub fn constraint_unblock(&mut self, job: u32) {
        let now = self.q.now();
        self.tracker.constraint_unblock(job as usize, now);
    }

    /// Mark `job` gang-blocked as of now (idempotent): matching free
    /// capacity was visible/probed, but never `Demand::slots` co-resident
    /// free slots on one node. Feeds the per-job `gang_wait` breakdown
    /// (see [`JobTracker::gang_block`]).
    pub fn gang_block(&mut self, job: u32) {
        let now = self.q.now();
        self.tracker.gang_block(job as usize, now);
    }

    /// Close `job`'s gang-blocked interval (no-op when not blocked).
    pub fn gang_unblock(&mut self, job: u32) {
        let now = self.q.now();
        self.tracker.gang_unblock(job as usize, now);
    }

    /// Whether every job in the trace has completed. Under sharded
    /// execution this reports the epoch-start snapshot (the same value in
    /// threaded and sequential mode), refreshed at every barrier.
    pub fn all_done(&self) -> bool {
        self.done_override.unwrap_or_else(|| self.tracker.all_done())
    }

    /// Whether the flight recorder is on — call sites that must *compute*
    /// a payload (e.g. staleness) gate on this so the off path does no
    /// work at all.
    #[inline]
    pub fn flight_on(&self) -> bool {
        self.rec.enabled()
    }

    /// Record one flight-recorder event at the current sim-time. A
    /// single predictable branch unless the run set `SimParams::flight`
    /// (`crate::obs::flight` documents the taxonomy and payloads).
    #[inline]
    pub fn flight(&mut self, kind: EvKind, actor: Actor, job: u32, task: u32, payload: u64) {
        let t = self.q.now();
        self.rec.record(t, kind, actor, job, task, payload);
    }
}

/// A scheduling architecture, expressed as reactions to events.
///
/// The driver calls [`init`](Scheduler::init) once (after arrival
/// injection — initial events get queue positions *after* all arrivals),
/// then dispatches every popped event to [`on_arrival`](Scheduler::on_arrival)
/// or [`on_event`](Scheduler::on_event) until the queue drains.
pub trait Scheduler {
    /// The scheduler's own event payload type.
    type Ev;

    /// Architecture name (for diagnostics and sweep tables).
    fn name(&self) -> &'static str;

    /// One-time setup: push recurring events (heartbeats), failure
    /// injections, etc. Default: nothing.
    fn init(&mut self, _ctx: &mut SimCtx<'_, Self::Ev>) {}

    /// A job from the trace arrived (index into `ctx.trace.jobs`).
    fn on_arrival(&mut self, job: u32, ctx: &mut SimCtx<'_, Self::Ev>);

    /// A scheduler-defined event fired.
    fn on_event(&mut self, ev: Self::Ev, ctx: &mut SimCtx<'_, Self::Ev>);
}

/// Run `sched` over `trace` to completion and collect the outcome.
///
/// Panics (via [`JobTracker::into_outcome`]) if the scheduler loses
/// tasks — a scheduler that strands work is a bug, not a statistic.
pub fn run<S: Scheduler>(sched: &mut S, params: &SimParams, trace: &Trace) -> RunOutcome {
    run_with_pools(sched, params, trace, BufPools::new())
}

/// [`run`] with an explicit buffer pool. Production always pools; tests
/// pass [`BufPools::disabled`] to pin that pooling is behavior-neutral.
pub fn run_with_pools<S: Scheduler>(
    sched: &mut S,
    params: &SimParams,
    trace: &Trace,
    mut pools: BufPools,
) -> RunOutcome {
    let mut rng = Rng::new(params.seed);
    let mut tracker = JobTracker::new(trace, params.short_threshold);
    let mut out = RunOutcome::default();
    let mut rec = FlightRecorder::new(params.flight);
    let mut q: EventQueue<DriverEv<S::Ev>> = EventQueue::new();

    for (i, j) in trace.jobs.iter().enumerate() {
        q.push(j.submit, DriverEv::Arrival(i as u32));
    }
    {
        let mut ctx = SimCtx {
            q: &mut q,
            rng: &mut rng,
            net: &params.net,
            tracker: &mut tracker,
            trace,
            out: &mut out,
            pool: &mut pools,
            route: None,
            done_override: None,
            rec: &mut rec,
        };
        sched.init(&mut ctx);
    }

    // started here — after arrival injection and scheduler init — so
    // `events/s` measures exactly the drain loop it claims to
    let t0 = Instant::now();
    while let Some((_, ev)) = q.pop() {
        let mut ctx = SimCtx {
            q: &mut q,
            rng: &mut rng,
            net: &params.net,
            tracker: &mut tracker,
            trace,
            out: &mut out,
            pool: &mut pools,
            route: None,
            done_override: None,
            rec: &mut rec,
        };
        match ev {
            DriverEv::Arrival(j) => sched.on_arrival(j, &mut ctx),
            DriverEv::Sched(e) => sched.on_event(e, &mut ctx),
        }
    }

    // capture before summarization so events/s measures the loop, not
    // the O(jobs) outcome collection below
    let sim_wall_s = t0.elapsed().as_secs_f64();

    debug_assert!(tracker.all_done(), "{} lost jobs", sched.name());
    let makespan = q.now();
    let mut outcome = tracker.into_outcome(makespan);
    outcome.inconsistencies = out.inconsistencies;
    outcome.tasks = out.tasks;
    outcome.messages = out.messages;
    outcome.decisions = out.decisions;
    outcome.constraint_rejections = out.constraint_rejections;
    outcome.gang_rejections = out.gang_rejections;
    outcome.tasks_killed = out.tasks_killed;
    outcome.tasks_rerun = out.tasks_rerun;
    outcome.work_lost_s = out.work_lost_s;
    outcome.redispatch_s = out.redispatch_s;
    outcome.breakdown = out.breakdown;
    outcome.events = q.popped();
    outcome.sim_wall_s = sim_wall_s;
    outcome.shards = 1;
    if rec.enabled() {
        flight::attach(&mut outcome, flight::merge(vec![rec]));
    }
    outcome
}

/// One shard of a sharded scheduler (see [`run_sharded`]). The shape
/// mirrors [`Scheduler`] minus `name`, plus `Send` bounds so a shard can
/// run on its own thread. A shard only ever sees events homed on it;
/// everything it pushes for other shards is diverted by the driver.
pub trait ShardSim: Send {
    /// The scheduler's own event payload type (shared by all shards).
    type Ev: Send;

    /// One-time setup for this shard (heartbeats for owned LMs, failure
    /// injection for owned GMs, ...). May push cross-shard events; they
    /// are delivered through the first epoch barrier.
    fn init(&mut self, ctx: &mut SimCtx<'_, Self::Ev>);

    /// A job homed on this shard arrived (index into `ctx.trace.jobs`).
    fn on_arrival(&mut self, job: u32, ctx: &mut SimCtx<'_, Self::Ev>);

    /// An event homed on this shard fired.
    fn on_event(&mut self, ev: Self::Ev, ctx: &mut SimCtx<'_, Self::Ev>);
}

/// Per-shard execution lane: the shard itself plus private copies of all
/// run state the sequential driver keeps singular — queue, RNG stream,
/// tracker, counters, buffer pools — and the epoch's exchange log.
struct ShardLane<S: ShardSim> {
    sim: S,
    q: EventQueue<DriverEv<S::Ev>>,
    rng: Rng,
    tracker: JobTracker,
    out: RunOutcome,
    pool: BufPools,
    /// Exchange log, bucketed by destination shard (length = shards).
    outbox: Vec<Vec<(SimTime, S::Ev)>>,
    /// Lane-private flight recorder; merged in fixed lane order at run
    /// end, so the merged log is identical in threaded and sequential
    /// modes (per-lane logs already are — `run_epoch` is shared).
    rec: FlightRecorder,
    /// Next sim time at which draining an event emits a `DrvEpoch`
    /// marker. Advanced to `t + window` on each marker, so the marker
    /// stream is a pure function of the lane's drained-event times and
    /// the window — *not* of how barrier horizons tile those times,
    /// which fast-forward deliberately changes on idle stretches.
    next_epoch_mark: SimTime,
}

impl<S: ShardSim> ShardLane<S> {
    /// Drain this lane's local events strictly below `horizon`. This is
    /// the *only* code that executes shard events — the threaded and
    /// sequential modes of [`run_sharded`] both call it, so they cannot
    /// diverge in per-event behavior, only in lane interleaving (which
    /// is invisible: lanes share no mutable state between barriers).
    #[allow(clippy::too_many_arguments)]
    fn run_epoch(
        &mut self,
        my_shard: usize,
        horizon: SimTime,
        window: SimTime,
        all_done: bool,
        shard_of: &(dyn Fn(&S::Ev) -> usize + Sync),
        net: &NetModel,
        trace: &Trace,
    ) {
        while let Some(t) = self.q.peek_time() {
            if t >= horizon {
                break;
            }
            let (_, ev) = self.q.pop().expect("peeked event vanished");
            if t >= self.next_epoch_mark {
                // one marker per window's worth of drained activity,
                // keyed off drained-event times rather than barrier
                // horizons: a lane's drained sequence is time-ordered
                // and identical whichever way idle stretches are tiled,
                // so fast-forwarded and dense runs (and threaded and
                // sequential lanes) log the same markers
                self.rec.record(
                    t,
                    EvKind::DrvEpoch,
                    Actor::Driver(my_shard as u32),
                    NONE,
                    NONE,
                    (t + window).as_micros(),
                );
                self.next_epoch_mark = t + window;
            }
            let mut ctx = SimCtx {
                q: &mut self.q,
                rng: &mut self.rng,
                net,
                tracker: &mut self.tracker,
                trace,
                out: &mut self.out,
                pool: &mut self.pool,
                route: Some(ShardRoute {
                    my_shard,
                    shard_of,
                    outbox: &mut self.outbox,
                }),
                done_override: Some(all_done),
                rec: &mut self.rec,
            };
            match ev {
                DriverEv::Arrival(j) => self.sim.on_arrival(j, &mut ctx),
                DriverEv::Sched(e) => self.sim.on_event(e, &mut ctx),
            }
        }
    }
}

/// The per-epoch barrier step of the *sequential* mode: replay every
/// lane's per-destination exchange buckets into the destination queues
/// (source-major, push order within a source — a fixed total order per
/// destination, so the queue's `(time, seq)` keys come out identical no
/// matter how the previous epoch's lanes interleaved), then pick the
/// next epoch base and snapshot global completion. Returns `None` when
/// every queue has drained. The threaded mode distributes exactly this
/// arithmetic across its workers (see [`run_sharded`]); the two stay
/// bit-identical because replay order, the horizon sequence, and the
/// completion snapshot are all pure functions of the same inputs.
fn barrier_step<S: ShardSim>(
    lanes: &mut [ShardLane<S>],
    window: SimTime,
    n_jobs: usize,
    prev_horizon: Option<SimTime>,
    fast_forward: bool,
) -> Option<(SimTime, bool)> {
    for s in 0..lanes.len() {
        let mut buckets = std::mem::take(&mut lanes[s].outbox);
        for (d, bucket) in buckets.iter_mut().enumerate() {
            for (at, ev) in bucket.drain(..) {
                // the lookahead contract: anything crossing shards is
                // net-delayed by >= `window`, so it lands at or beyond
                // the horizon of the epoch that produced it
                debug_assert!(
                    prev_horizon.is_none_or(|h| at >= h),
                    "cross-shard event at {at:?} undercuts epoch horizon {prev_horizon:?}"
                );
                lanes[d].q.push(at, DriverEv::Sched(ev));
            }
        }
        lanes[s].outbox = buckets; // keep the buckets' capacity across epochs
    }
    let min_next = lanes.iter_mut().filter_map(|l| l.q.peek_time()).min()?;
    // idle-epoch fast-forward (default): base the next epoch at the
    // global minimum next-event time, so a sparse stretch costs one
    // epoch instead of thousands. Off: tile the clock densely from the
    // previous horizon — on constant-delay nets the two schedules drain
    // every event at the same horizon, so they are bit-identical
    // (pinned by `tests/shard_identity.rs`; argument in DESIGN.md).
    let t0 = match prev_horizon {
        Some(h) if !fast_forward => h,
        _ => min_next,
    };
    // lane 0 logs the fast-forward (the threaded mode's worker 0 runs
    // the same (prev_horizon, min) arithmetic, so the logs agree)
    if fast_forward {
        if let Some(h) = prev_horizon {
            if min_next > h {
                lanes[0].rec.record(
                    min_next,
                    EvKind::DrvFastForward,
                    Actor::Driver(0),
                    NONE,
                    NONE,
                    (min_next - h).as_micros(),
                );
            }
        }
    }
    let done = lanes.iter().map(|l| l.tracker.done()).sum::<usize>() == n_jobs;
    Some((t0 + window, done))
}

/// Why a run configured with `--shards N` must delegate to the classic
/// sequential driver instead of entering [`run_sharded`]: the plan
/// clamped to a single shard (topology too small for the requested
/// count), or the network model has no positive minimum delay — i.e.
/// no conservative-lookahead window (e.g. `Jittered { base: 0 }`).
/// Scheduler front-ends call this *before* `run_sharded` (whose asserts
/// stay as a hard backstop) and record the returned reason on
/// [`RunOutcome::shard_fallback`] so clamping is never silent.
pub fn shard_fallback(effective_shards: usize, params: &SimParams) -> Option<ShardFallback> {
    if effective_shards <= 1 {
        Some(ShardFallback::PlanClamped)
    } else if params.net.min_delay() == SimTime::ZERO {
        Some(ShardFallback::ZeroWindow)
    } else {
        None
    }
}

/// Run a sharded scheduler over `trace` to completion — the parallel
/// (`threaded = true`) or sequential-reference counterpart of [`run`].
///
/// Conservative lookahead: the epoch window is the network model's
/// minimum one-way delay. Within an epoch `[t0, t0 + window)` every lane
/// drains only its local queue; pushes homed on other shards divert to
/// the lane's per-destination exchange buckets. Because every
/// cross-shard message is net-delayed by at least the window, a message
/// produced inside an epoch is always addressed at or beyond that
/// epoch's horizon — no lane can miss an input for the window it is
/// draining, so per-lane execution needs no locks and no rollback. At
/// the barrier the buckets are replayed source-major per destination
/// (see [`barrier_step`]), which makes the two modes bit-identical:
/// `tests/shard_identity.rs` pins record-level equality across thread
/// counts.
///
/// The threaded mode is SPMD: the main thread seeds shared state and
/// then the `n` workers run the whole epoch loop themselves against a
/// `Barrier::new(n)`, an n×n exchange matrix, and triple-buffered
/// atomic slots carrying each window's (global min next-event, traffic,
/// completions). An epoch that produced cross-shard traffic is followed
/// by one replay window — the "second barrier crossing" — in which
/// every worker drains its matrix column; an epoch with zero traffic
/// skips it and goes straight to the next drain. Idle-epoch
/// fast-forward (`SimParams::fast_forward`, default on) bases each
/// epoch at the global minimum next-event time computed identically in
/// both modes.
///
/// Each lane draws from its own seed-decorrelated RNG stream (a shared
/// stream would need a global draw order, which parallel execution
/// cannot reproduce). Shard 0 keeps the run seed, so a 1-shard run is
/// stream-compatible with the sequential driver.
pub fn run_sharded<S: ShardSim>(
    shards: Vec<S>,
    shard_of: &(dyn Fn(&S::Ev) -> usize + Sync),
    shard_of_job: &dyn Fn(u32) -> usize,
    params: &SimParams,
    trace: &Trace,
    threaded: bool,
) -> RunOutcome {
    let n = shards.len();
    let window = params.net.min_delay();
    // hard backstop behind the `shard_fallback` pre-check that
    // scheduler front-ends run (and record) before calling in here
    assert!(n >= 1, "run_sharded needs at least one shard");
    assert!(
        window > SimTime::ZERO,
        "sharded execution needs a positive network-delay floor for lookahead \
         (callers gate on `shard_fallback` and delegate to the classic driver)"
    );
    let n_jobs = trace.n_jobs();

    let mut lanes: Vec<ShardLane<S>> = shards
        .into_iter()
        .enumerate()
        .map(|(s, sim)| ShardLane {
            sim,
            q: EventQueue::new(),
            // decorrelated per-shard streams; the same golden-ratio mix
            // as Rng::fork, and mix(0) = 0 keeps shard 0 on the run seed
            rng: Rng::new(params.seed ^ (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            tracker: JobTracker::new(trace, params.short_threshold),
            out: RunOutcome::default(),
            pool: BufPools::new(),
            outbox: (0..n).map(|_| Vec::new()).collect(),
            rec: FlightRecorder::new(params.flight),
            next_epoch_mark: SimTime::ZERO,
        })
        .collect();

    // arrivals in global trace order, each on its owning shard — within
    // a shard they keep the same relative (time, seq) order the
    // sequential driver gives them
    for (i, j) in trace.jobs.iter().enumerate() {
        lanes[shard_of_job(i as u32)]
            .q
            .push(j.submit, DriverEv::Arrival(i as u32));
    }
    for (s, lane) in lanes.iter_mut().enumerate() {
        let mut ctx = SimCtx {
            q: &mut lane.q,
            rng: &mut lane.rng,
            net: &params.net,
            tracker: &mut lane.tracker,
            trace,
            out: &mut lane.out,
            pool: &mut lane.pool,
            route: Some(ShardRoute {
                my_shard: s,
                shard_of,
                outbox: &mut lane.outbox,
            }),
            done_override: Some(false),
            rec: &mut lane.rec,
        };
        lane.sim.init(&mut ctx);
    }

    // started here — after arrival injection and shard init — so
    // `events/s` measures the epoch loop, not setup (mirrors the
    // classic driver's drain-loop-only accounting)
    let t0 = Instant::now();
    if threaded && n > 1 {
        use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
        use std::sync::{Barrier, Mutex};

        // "queue empty" sentinel in the min-next slots
        const IDLE_MIN: u64 = u64::MAX;

        // SPMD epoch loop: persistent workers own their lanes outright
        // (epochs number in the millions — spawning or lock-handoff per
        // epoch would dwarf the event work) and coordinate through one
        // n-way barrier. Per-window shared values are triple-buffered
        // by window index: in window k every worker reads slot (k+2)%3
        // (the previous window's publications), resets slot (k+1)%3 for
        // the next window, and publishes into slot k%3 — the three
        // roles always hit three distinct slots, and consecutive
        // touches of any one slot are separated by a barrier crossing,
        // so Relaxed atomics suffice (the barrier provides the
        // happens-before edges).
        struct EpochSlots {
            min_next: [AtomicU64; 3], // global min next-event µs, via fetch_min
            traffic: [AtomicU64; 3],  // cross-shard events produced, via fetch_add
            done: [AtomicU64; 3],     // newly completed jobs, via fetch_add
        }
        let slots = EpochSlots {
            min_next: [IDLE_MIN; 3].map(AtomicU64::new),
            traffic: [0; 3].map(AtomicU64::new),
            done: [0; 3].map(AtomicU64::new),
        };

        // n×n exchange matrix: cell (s, d) carries events from shard s
        // to shard d. Barrier discipline makes every cell single-owner
        // at any instant — written by worker s in drain windows (when
        // it is empty, so a swap both publishes the bucket and recycles
        // the cell's capacity), drained by worker d in the replay
        // window that every traffic-producing window forces next. The
        // mutexes are therefore uncontended; they exist for the type
        // system.
        let cells: Vec<Vec<Mutex<Vec<(SimTime, S::Ev)>>>> = (0..n)
            .map(|_| (0..n).map(|_| Mutex::new(Vec::new())).collect())
            .collect();

        // seed window 0's read slot ((0+2)%3 = 2) with the post-init
        // state: deposit init-time cross-shard events into the matrix
        // and publish their count plus the global min next-event time
        let mut init_traffic = 0u64;
        for (s, lane) in lanes.iter_mut().enumerate() {
            for (d, bucket) in lane.outbox.iter_mut().enumerate() {
                if !bucket.is_empty() {
                    init_traffic += bucket.len() as u64;
                    let mut cell = cells[s][d].lock().expect("exchange cell poisoned");
                    std::mem::swap(&mut *cell, bucket);
                }
            }
        }
        let init_min = lanes
            .iter_mut()
            .filter_map(|l| l.q.peek_time())
            .min()
            .map_or(IDLE_MIN, |t| t.as_micros());
        slots.min_next[2].store(init_min, Relaxed);
        slots.traffic[2].store(init_traffic, Relaxed);

        let barrier = Barrier::new(n);
        let fast_forward = params.fast_forward;
        lanes = std::thread::scope(|scope| {
            let handles: Vec<_> = lanes
                .into_iter()
                .enumerate()
                .map(|(me, mut lane)| {
                    let (barrier, slots, cells) = (&barrier, &slots, &cells);
                    let net = &params.net;
                    scope.spawn(move || {
                        let mut k = 0usize; // window index
                        let mut prev_horizon: Option<SimTime> = None;
                        let mut done_cum = 0usize; // completions through window k-1
                        let mut done_published = 0usize;
                        loop {
                            let (read, write, reset) = ((k + 2) % 3, k % 3, (k + 1) % 3);
                            let traffic_prev = slots.traffic[read].load(Relaxed);
                            let min_prev = slots.min_next[read].load(Relaxed);
                            done_cum += slots.done[read].load(Relaxed) as usize;
                            slots.min_next[reset].store(IDLE_MIN, Relaxed);
                            slots.traffic[reset].store(0, Relaxed);
                            slots.done[reset].store(0, Relaxed);
                            if traffic_prev > 0 {
                                // replay window — the previous window
                                // produced cross-shard traffic, so every
                                // worker drains its matrix column:
                                // source-major, push order within a
                                // source, the same per-destination total
                                // order the sequential replay uses. No
                                // events run here; this is the "second
                                // barrier crossing", and zero-traffic
                                // windows skip it entirely.
                                for row in cells {
                                    let mut cell =
                                        row[me].lock().expect("exchange cell poisoned");
                                    for (at, ev) in cell.drain(..) {
                                        debug_assert!(
                                            prev_horizon.is_none_or(|h| at >= h),
                                            "cross-shard event at {at:?} undercuts epoch \
                                             horizon {prev_horizon:?}"
                                        );
                                        lane.q.push(at, DriverEv::Sched(ev));
                                    }
                                }
                                if let Some(t) = lane.q.peek_time() {
                                    slots.min_next[write].fetch_min(t.as_micros(), Relaxed);
                                }
                                barrier.wait();
                                k += 1;
                                continue;
                            }
                            if min_prev == IDLE_MIN {
                                // every queue drained and nothing in
                                // flight; all workers read the same pair
                                // and terminate in the same window
                                break;
                            }
                            // drain window: the same horizon arithmetic
                            // as the sequential `barrier_step`
                            let m = SimTime::from_micros(min_prev);
                            let horizon = match prev_horizon {
                                Some(h) if !fast_forward => h + window,
                                _ => m + window,
                            };
                            // worker 0 logs the fast-forward with the
                            // same (prev_horizon, min) arithmetic the
                            // sequential `barrier_step` uses, keeping
                            // lane 0's log identical across modes
                            if me == 0 && fast_forward {
                                if let Some(h) = prev_horizon {
                                    if m > h {
                                        lane.rec.record(
                                            m,
                                            EvKind::DrvFastForward,
                                            Actor::Driver(0),
                                            NONE,
                                            NONE,
                                            (m - h).as_micros(),
                                        );
                                    }
                                }
                            }
                            let all_done = done_cum == n_jobs;
                            lane.run_epoch(me, horizon, window, all_done, shard_of, net, trace);
                            let mut traffic = 0u64;
                            for (d, bucket) in lane.outbox.iter_mut().enumerate() {
                                if !bucket.is_empty() {
                                    traffic += bucket.len() as u64;
                                    let mut cell =
                                        cells[me][d].lock().expect("exchange cell poisoned");
                                    debug_assert!(cell.is_empty(), "cell not drained by replay");
                                    std::mem::swap(&mut *cell, bucket);
                                }
                            }
                            if traffic > 0 {
                                slots.traffic[write].fetch_add(traffic, Relaxed);
                            }
                            if let Some(t) = lane.q.peek_time() {
                                slots.min_next[write].fetch_min(t.as_micros(), Relaxed);
                            }
                            let done_now = lane.tracker.done();
                            if done_now > done_published {
                                slots.done[write]
                                    .fetch_add((done_now - done_published) as u64, Relaxed);
                                done_published = done_now;
                            }
                            prev_horizon = Some(horizon);
                            barrier.wait();
                            k += 1;
                        }
                        lane
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        });
    } else {
        let mut prev_horizon: Option<SimTime> = None;
        loop {
            let Some((horizon, all_done)) =
                barrier_step(&mut lanes, window, n_jobs, prev_horizon, params.fast_forward)
            else {
                break;
            };
            prev_horizon = Some(horizon);
            for (s, lane) in lanes.iter_mut().enumerate() {
                lane.run_epoch(s, horizon, window, all_done, shard_of, &params.net, trace);
            }
        }
    }

    let sim_wall_s = t0.elapsed().as_secs_f64();

    // merge in fixed lane order (identical in both modes; f64 sums are
    // order-sensitive, so this matters for bit-identity)
    let makespan = lanes
        .iter()
        .map(|l| l.q.now())
        .max()
        .unwrap_or(SimTime::ZERO);
    let events: u64 = lanes.iter().map(|l| l.q.popped()).sum();
    let mut totals = RunOutcome::default();
    let mut trackers = Vec::with_capacity(n);
    let mut recorders = Vec::with_capacity(n);
    for lane in lanes {
        totals.inconsistencies += lane.out.inconsistencies;
        totals.tasks += lane.out.tasks;
        totals.messages += lane.out.messages;
        totals.decisions += lane.out.decisions;
        totals.constraint_rejections += lane.out.constraint_rejections;
        totals.gang_rejections += lane.out.gang_rejections;
        totals.tasks_killed += lane.out.tasks_killed;
        totals.tasks_rerun += lane.out.tasks_rerun;
        totals.work_lost_s += lane.out.work_lost_s;
        totals.redispatch_s.extend(lane.out.redispatch_s);
        totals.breakdown.queue_scheduler_s += lane.out.breakdown.queue_scheduler_s;
        totals.breakdown.proc_s += lane.out.breakdown.proc_s;
        totals.breakdown.comm_s += lane.out.breakdown.comm_s;
        totals.breakdown.queue_worker_s += lane.out.breakdown.queue_worker_s;
        totals.breakdown.exec_s += lane.out.breakdown.exec_s;
        trackers.push(lane.tracker);
        recorders.push(lane.rec);
    }
    let mut outcome = JobTracker::merge_into_outcome(trackers, makespan);
    outcome.inconsistencies = totals.inconsistencies;
    outcome.tasks = totals.tasks;
    outcome.messages = totals.messages;
    outcome.decisions = totals.decisions;
    outcome.constraint_rejections = totals.constraint_rejections;
    outcome.gang_rejections = totals.gang_rejections;
    outcome.tasks_killed = totals.tasks_killed;
    outcome.tasks_rerun = totals.tasks_rerun;
    outcome.work_lost_s = totals.work_lost_s;
    outcome.redispatch_s = totals.redispatch_s;
    outcome.breakdown = totals.breakdown;
    outcome.events = events;
    outcome.sim_wall_s = sim_wall_s;
    outcome.shards = n as u32;
    if params.flight {
        // fixed lane order + stable time sort: identical in both modes
        flight::attach(&mut outcome, flight::merge(recorders));
    }
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::synthetic::synthetic_fixed;

    /// Toy scheduler: runs every task immediately on arrival (infinite
    /// DC), completion after one network hop.
    struct Immediate;

    enum ToyEv {
        Done { job: u32 },
    }

    impl Scheduler for Immediate {
        type Ev = ToyEv;

        fn name(&self) -> &'static str {
            "immediate"
        }

        fn on_arrival(&mut self, job: u32, ctx: &mut SimCtx<'_, ToyEv>) {
            let durs = ctx.trace.jobs[job as usize].durations.clone();
            for dur in durs {
                ctx.out.tasks += 1;
                ctx.out.decisions += 1;
                let d = ctx.net_delay();
                ctx.push_after(dur + d, ToyEv::Done { job });
            }
        }

        fn on_event(&mut self, ev: ToyEv, ctx: &mut SimCtx<'_, ToyEv>) {
            match ev {
                ToyEv::Done { job } => {
                    ctx.out.messages += 1;
                    ctx.task_done(job);
                }
            }
        }
    }

    #[test]
    fn driver_completes_all_jobs() {
        let trace = synthetic_fixed(5, 10, 1.0, 0.5, 100, 1);
        let params = SimParams::default();
        let out = run(&mut Immediate, &params, &trace);
        assert_eq!(out.jobs.len(), 10);
        assert_eq!(out.tasks as usize, trace.n_tasks());
        assert_eq!(out.messages as usize, trace.n_tasks());
        // every job finishes one hop after its longest task
        for (r, j) in out.jobs.iter().zip(trace.jobs.iter()) {
            assert_eq!(r.complete, j.submit + j.ideal_jct() + SimTime::from_millis(0.5));
        }
    }

    #[test]
    fn pools_recycle_buffers() {
        let mut p = BufPools::new();
        let mut v: Vec<u32> = p.take();
        v.extend([1, 2, 3]);
        let cap = v.capacity();
        p.give(v);
        let v2: Vec<u32> = p.take();
        assert!(v2.is_empty());
        assert_eq!(v2.capacity(), cap);
        // distinct element types pool independently
        let mut w: Vec<(u32, u32)> = p.take();
        w.push((1, 2));
        p.give(w);
        let w2: Vec<(u32, u32)> = p.take();
        assert!(w2.is_empty());
        assert!(w2.capacity() >= 1);
    }

    #[test]
    fn disabled_pools_always_allocate_fresh() {
        let mut p = BufPools::disabled();
        let mut v: Vec<u32> = p.take();
        v.extend([1, 2, 3]);
        p.give(v);
        let v2: Vec<u32> = p.take();
        assert_eq!(v2.capacity(), 0);
    }

    #[test]
    fn run_reports_event_throughput() {
        let trace = synthetic_fixed(5, 10, 1.0, 0.5, 100, 1);
        let params = SimParams::default();
        let out = run(&mut Immediate, &params, &trace);
        // every arrival plus every task completion is one event
        assert_eq!(out.events as usize, trace.n_jobs() + trace.n_tasks());
        assert!(out.sim_wall_s >= 0.0);
    }

    #[test]
    fn driver_is_deterministic() {
        let trace = synthetic_fixed(8, 12, 1.0, 0.7, 80, 2);
        let mut params = SimParams::default();
        params.seed = 9;
        let a = run(&mut Immediate, &params, &trace);
        let b = run(&mut Immediate, &params, &trace);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.messages, b.messages);
    }
}
