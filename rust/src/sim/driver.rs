//! Shared simulation driver: the one event loop all schedulers run on.
//!
//! Before this layer existed every scheduler hand-rolled the same loop:
//! push trace arrivals, pop events, thread `(queue, rng, tracker, out)`
//! through every handler, then merge counters into a [`RunOutcome`]. The
//! driver owns that plumbing; a scheduler only supplies its event payload
//! type and the per-event logic via the [`Scheduler`] trait.
//!
//! Determinism contract: the driver injects one [`DriverEv::Arrival`] per
//! trace job *before* calling [`Scheduler::init`], so arrival events
//! occupy the same `(time, seq)` slots the hand-rolled loops gave them,
//! and the single [`Rng`] (seeded from `SimParams::seed`) is handed to
//! handlers through [`SimCtx`] in event order. A port of a hand-rolled
//! loop that draws randomness and pushes events in the same order is
//! therefore *bit-identical* to its pre-driver behavior — the golden
//! tests in `tests/driver_invariants.rs` pin this down.

use std::any::{Any, TypeId};
use std::time::Instant;

use crate::config::SimParams;
use crate::metrics::RunOutcome;
use crate::sched::common::JobTracker;
use crate::sim::event::EventQueue;
use crate::sim::net::NetModel;
use crate::sim::time::SimTime;
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Per-pool cap on retained buffers of one element type.
const POOL_CAP: usize = 64;

/// Recycled `Vec<T>` buffers, keyed by element type.
///
/// Message payloads (`Vec<Mapping>` verification batches, `Vec<(u32,
/// u32)>` inconsistency replies, probe/duration vectors) used to be
/// malloc-per-message on the hot path. Handlers instead [`take`] a
/// cleared buffer (reusing a previous message's capacity) and [`give`]
/// it back once the payload is consumed. Pooling never touches the RNG
/// or event order, so it is behavior-neutral by construction —
/// `tests/driver_invariants.rs` pins bit-identity against
/// [`BufPools::disabled`], where `take` always allocates fresh.
///
/// [`take`]: BufPools::take
/// [`give`]: BufPools::give
pub struct BufPools {
    /// One stack of spare buffers per element type seen so far. The
    /// linear scan is over a handful of entries (one per payload type a
    /// scheduler uses), far cheaper than hashing.
    slots: Vec<(TypeId, Box<dyn Any>)>,
    enabled: bool,
}

impl Default for BufPools {
    fn default() -> Self {
        Self::new()
    }
}

impl BufPools {
    pub fn new() -> BufPools {
        BufPools {
            slots: Vec::new(),
            enabled: true,
        }
    }

    /// A pass-through pool: `take` always allocates and `give` drops.
    /// Tests run schedulers on this to prove pooling changes nothing.
    pub fn disabled() -> BufPools {
        BufPools {
            slots: Vec::new(),
            enabled: false,
        }
    }

    /// Get a cleared buffer, reusing a recycled one when available.
    pub fn take<T: 'static>(&mut self) -> Vec<T> {
        if self.enabled {
            let id = TypeId::of::<T>();
            for (tid, stack) in &mut self.slots {
                if *tid == id {
                    let stack = stack
                        .downcast_mut::<Vec<Vec<T>>>()
                        .expect("pool slot holds its keyed type");
                    return stack.pop().unwrap_or_default();
                }
            }
        }
        Vec::new()
    }

    /// Return a buffer for reuse (cleared here; contents are dropped).
    pub fn give<T: 'static>(&mut self, mut v: Vec<T>) {
        if !self.enabled || v.capacity() == 0 {
            return;
        }
        v.clear();
        let id = TypeId::of::<T>();
        for (tid, stack) in &mut self.slots {
            if *tid == id {
                let stack = stack
                    .downcast_mut::<Vec<Vec<T>>>()
                    .expect("pool slot holds its keyed type");
                if stack.len() < POOL_CAP {
                    stack.push(v);
                }
                return;
            }
        }
        let stack: Vec<Vec<T>> = vec![v];
        self.slots.push((id, Box::new(stack)));
    }
}

/// Driver-level event: trace arrivals are injected by the driver itself;
/// everything else is the scheduler's own payload type.
pub enum DriverEv<E> {
    /// Job `.0` (trace index) reaches its scheduler.
    Arrival(u32),
    /// A scheduler-defined event.
    Sched(E),
}

/// Everything a scheduler may touch during one event: the clock, the
/// event queue (wrapped so schedulers can only push their own payloads),
/// the run's RNG and network model, the trace, completion bookkeeping,
/// and the run-wide counters.
pub struct SimCtx<'a, E> {
    q: &'a mut EventQueue<DriverEv<E>>,
    /// The run's single deterministic RNG (draw order = event order).
    pub rng: &'a mut Rng,
    net: &'a NetModel,
    tracker: &'a mut JobTracker,
    /// The workload being scheduled (read-only).
    pub trace: &'a Trace,
    /// Run-wide counters; merged into the final [`RunOutcome`].
    pub out: &'a mut RunOutcome,
    /// Recycled message-payload buffers (see [`BufPools`]).
    pub pool: &'a mut BufPools,
}

impl<E> SimCtx<'_, E> {
    /// Current simulated time (the timestamp of the event being handled).
    pub fn now(&self) -> SimTime {
        self.q.now()
    }

    /// Schedule `ev` at absolute time `at`.
    pub fn push(&mut self, at: SimTime, ev: E) {
        self.q.push(at, DriverEv::Sched(ev));
    }

    /// Schedule `ev` after a delay from now.
    pub fn push_after(&mut self, delay: SimTime, ev: E) {
        self.q.push_after(delay, DriverEv::Sched(ev));
    }

    /// Draw one network latency from the run's model.
    pub fn net_delay(&mut self) -> SimTime {
        self.net.delay(self.rng)
    }

    /// Send `ev` over the network: one latency draw, one message counted,
    /// delivery scheduled after the drawn delay.
    pub fn send(&mut self, ev: E) {
        let d = self.net_delay();
        self.out.messages += 1;
        self.push_after(d, ev);
    }

    /// Record one finished task of `job`; returns true if the job is done.
    pub fn task_done(&mut self, job: u32) -> bool {
        let now = self.q.now();
        self.tracker.task_done(self.trace, job as usize, now)
    }

    /// Mark `job` constraint-blocked as of now (idempotent): a placement
    /// failed purely because of the job's demand. Feeds the per-job
    /// `constraint_wait` breakdown (see [`JobTracker::constraint_block`]).
    pub fn constraint_block(&mut self, job: u32) {
        let now = self.q.now();
        self.tracker.constraint_block(job as usize, now);
    }

    /// Close `job`'s constraint-blocked interval (no-op when not blocked).
    pub fn constraint_unblock(&mut self, job: u32) {
        let now = self.q.now();
        self.tracker.constraint_unblock(job as usize, now);
    }

    /// Mark `job` gang-blocked as of now (idempotent): matching free
    /// capacity was visible/probed, but never `Demand::slots` co-resident
    /// free slots on one node. Feeds the per-job `gang_wait` breakdown
    /// (see [`JobTracker::gang_block`]).
    pub fn gang_block(&mut self, job: u32) {
        let now = self.q.now();
        self.tracker.gang_block(job as usize, now);
    }

    /// Close `job`'s gang-blocked interval (no-op when not blocked).
    pub fn gang_unblock(&mut self, job: u32) {
        let now = self.q.now();
        self.tracker.gang_unblock(job as usize, now);
    }

    /// Whether every job in the trace has completed.
    pub fn all_done(&self) -> bool {
        self.tracker.all_done()
    }
}

/// A scheduling architecture, expressed as reactions to events.
///
/// The driver calls [`init`](Scheduler::init) once (after arrival
/// injection — initial events get queue positions *after* all arrivals),
/// then dispatches every popped event to [`on_arrival`](Scheduler::on_arrival)
/// or [`on_event`](Scheduler::on_event) until the queue drains.
pub trait Scheduler {
    /// The scheduler's own event payload type.
    type Ev;

    /// Architecture name (for diagnostics and sweep tables).
    fn name(&self) -> &'static str;

    /// One-time setup: push recurring events (heartbeats), failure
    /// injections, etc. Default: nothing.
    fn init(&mut self, _ctx: &mut SimCtx<'_, Self::Ev>) {}

    /// A job from the trace arrived (index into `ctx.trace.jobs`).
    fn on_arrival(&mut self, job: u32, ctx: &mut SimCtx<'_, Self::Ev>);

    /// A scheduler-defined event fired.
    fn on_event(&mut self, ev: Self::Ev, ctx: &mut SimCtx<'_, Self::Ev>);
}

/// Run `sched` over `trace` to completion and collect the outcome.
///
/// Panics (via [`JobTracker::into_outcome`]) if the scheduler loses
/// tasks — a scheduler that strands work is a bug, not a statistic.
pub fn run<S: Scheduler>(sched: &mut S, params: &SimParams, trace: &Trace) -> RunOutcome {
    run_with_pools(sched, params, trace, BufPools::new())
}

/// [`run`] with an explicit buffer pool. Production always pools; tests
/// pass [`BufPools::disabled`] to pin that pooling is behavior-neutral.
pub fn run_with_pools<S: Scheduler>(
    sched: &mut S,
    params: &SimParams,
    trace: &Trace,
    mut pools: BufPools,
) -> RunOutcome {
    let t0 = Instant::now();
    let mut rng = Rng::new(params.seed);
    let mut tracker = JobTracker::new(trace, params.short_threshold);
    let mut out = RunOutcome::default();
    let mut q: EventQueue<DriverEv<S::Ev>> = EventQueue::new();

    for (i, j) in trace.jobs.iter().enumerate() {
        q.push(j.submit, DriverEv::Arrival(i as u32));
    }
    {
        let mut ctx = SimCtx {
            q: &mut q,
            rng: &mut rng,
            net: &params.net,
            tracker: &mut tracker,
            trace,
            out: &mut out,
            pool: &mut pools,
        };
        sched.init(&mut ctx);
    }

    while let Some((_, ev)) = q.pop() {
        let mut ctx = SimCtx {
            q: &mut q,
            rng: &mut rng,
            net: &params.net,
            tracker: &mut tracker,
            trace,
            out: &mut out,
            pool: &mut pools,
        };
        match ev {
            DriverEv::Arrival(j) => sched.on_arrival(j, &mut ctx),
            DriverEv::Sched(e) => sched.on_event(e, &mut ctx),
        }
    }

    // capture before summarization so events/s measures the loop, not
    // the O(jobs) outcome collection below
    let sim_wall_s = t0.elapsed().as_secs_f64();

    debug_assert!(tracker.all_done(), "{} lost jobs", sched.name());
    let makespan = q.now();
    let mut outcome = tracker.into_outcome(makespan);
    outcome.inconsistencies = out.inconsistencies;
    outcome.tasks = out.tasks;
    outcome.messages = out.messages;
    outcome.decisions = out.decisions;
    outcome.constraint_rejections = out.constraint_rejections;
    outcome.gang_rejections = out.gang_rejections;
    outcome.breakdown = out.breakdown;
    outcome.events = q.popped();
    outcome.sim_wall_s = sim_wall_s;
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::synthetic::synthetic_fixed;

    /// Toy scheduler: runs every task immediately on arrival (infinite
    /// DC), completion after one network hop.
    struct Immediate;

    enum ToyEv {
        Done { job: u32 },
    }

    impl Scheduler for Immediate {
        type Ev = ToyEv;

        fn name(&self) -> &'static str {
            "immediate"
        }

        fn on_arrival(&mut self, job: u32, ctx: &mut SimCtx<'_, ToyEv>) {
            let durs = ctx.trace.jobs[job as usize].durations.clone();
            for dur in durs {
                ctx.out.tasks += 1;
                ctx.out.decisions += 1;
                let d = ctx.net_delay();
                ctx.push_after(dur + d, ToyEv::Done { job });
            }
        }

        fn on_event(&mut self, ev: ToyEv, ctx: &mut SimCtx<'_, ToyEv>) {
            match ev {
                ToyEv::Done { job } => {
                    ctx.out.messages += 1;
                    ctx.task_done(job);
                }
            }
        }
    }

    #[test]
    fn driver_completes_all_jobs() {
        let trace = synthetic_fixed(5, 10, 1.0, 0.5, 100, 1);
        let params = SimParams::default();
        let out = run(&mut Immediate, &params, &trace);
        assert_eq!(out.jobs.len(), 10);
        assert_eq!(out.tasks as usize, trace.n_tasks());
        assert_eq!(out.messages as usize, trace.n_tasks());
        // every job finishes one hop after its longest task
        for (r, j) in out.jobs.iter().zip(trace.jobs.iter()) {
            assert_eq!(r.complete, j.submit + j.ideal_jct() + SimTime::from_millis(0.5));
        }
    }

    #[test]
    fn pools_recycle_buffers() {
        let mut p = BufPools::new();
        let mut v: Vec<u32> = p.take();
        v.extend([1, 2, 3]);
        let cap = v.capacity();
        p.give(v);
        let v2: Vec<u32> = p.take();
        assert!(v2.is_empty());
        assert_eq!(v2.capacity(), cap);
        // distinct element types pool independently
        let mut w: Vec<(u32, u32)> = p.take();
        w.push((1, 2));
        p.give(w);
        let w2: Vec<(u32, u32)> = p.take();
        assert!(w2.is_empty());
        assert!(w2.capacity() >= 1);
    }

    #[test]
    fn disabled_pools_always_allocate_fresh() {
        let mut p = BufPools::disabled();
        let mut v: Vec<u32> = p.take();
        v.extend([1, 2, 3]);
        p.give(v);
        let v2: Vec<u32> = p.take();
        assert_eq!(v2.capacity(), 0);
    }

    #[test]
    fn run_reports_event_throughput() {
        let trace = synthetic_fixed(5, 10, 1.0, 0.5, 100, 1);
        let params = SimParams::default();
        let out = run(&mut Immediate, &params, &trace);
        // every arrival plus every task completion is one event
        assert_eq!(out.events as usize, trace.n_jobs() + trace.n_tasks());
        assert!(out.sim_wall_s >= 0.0);
    }

    #[test]
    fn driver_is_deterministic() {
        let trace = synthetic_fixed(8, 12, 1.0, 0.7, 80, 2);
        let mut params = SimParams::default();
        params.seed = 9;
        let a = run(&mut Immediate, &params, &trace);
        let b = run(&mut Immediate, &params, &trace);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.messages, b.messages);
    }
}
