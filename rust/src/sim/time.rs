//! Simulated time as integer microseconds — exact comparisons, total order.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time (microseconds since simulation start).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs(s: f64) -> SimTime {
        debug_assert!(s >= 0.0, "negative time {s}");
        SimTime((s * 1e6).round() as u64)
    }

    pub fn from_micros(us: u64) -> SimTime {
        SimTime(us)
    }

    pub fn from_millis(ms: f64) -> SimTime {
        SimTime::from_secs(ms / 1e3)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_micros(self) -> u64 {
        self.0
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        debug_assert!(self.0 >= rhs.0, "time underflow {} - {}", self.0, rhs.0);
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}", self.as_secs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_secs() {
        let t = SimTime::from_secs(1.5);
        assert_eq!(t.as_micros(), 1_500_000);
        assert_eq!(t.as_secs(), 1.5);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::from_secs(2.0);
        let b = SimTime::from_millis(500.0);
        assert_eq!((a + b).as_secs(), 2.5);
        assert_eq!((a - b).as_secs(), 1.5);
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
    }

    #[test]
    fn ordering_is_exact() {
        assert!(SimTime::from_micros(1) < SimTime::from_micros(2));
        assert_eq!(SimTime::from_secs(0.0000005), SimTime::from_micros(1)); // rounds
    }
}
