//! Parallel multi-seed / multi-scenario sweep harness.
//!
//! One *sweep* fans a single experiment out over `frameworks × scenarios
//! × seeds` runs across OS threads. Every run is an independent, fully
//! deterministic simulation (the unified [`crate::sim::driver`] makes
//! all four architectures pure functions of `(config, trace, seed)`), so
//! the sweep is embarrassingly parallel and its aggregate output is
//! bit-identical regardless of thread count or completion order.
//!
//! Seeding: the per-run seed is [`run_seed`]`(base, scenario, rep)` — a
//! SplitMix64-style mix, so seeds are decorrelated across the grid but
//! *shared across frameworks*: every architecture sees the same trace
//! for a given (scenario, rep), which is what makes cross-framework
//! comparisons paired rather than noise-on-noise.
//!
//! The underlying thread-pool primitive, [`parallel_map`], is exported
//! for the experiment harness (Fig. 2/3, Table 1 regeneration run their
//! independent cells through it).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::cluster::shard::ShardPlan;
use crate::cluster::NodeCatalog;
use crate::config::{EagleConfig, MeghaConfig, PigeonConfig, SparrowConfig};
use crate::metrics::{
    summarize_constrained, summarize_constraint_wait, summarize_gang, summarize_gang_wait,
    summarize_jobs, DelaySummary, RunOutcome, ShardFallback,
};
use crate::obs::flight::FlightStats;
use crate::runtime::match_engine::RustMatchEngine;
use crate::sched;
use crate::sched::megha::FailurePlan;
use crate::sim::fault::{FaultPlan, FaultSpec, NetDegrade};
use crate::sim::net::NetModel;
use crate::sim::time::SimTime;
use crate::util::stats::{mean, percentile};
use crate::workload::constraints::{apply_constraints, CONSTRAIN_SEED};
use crate::workload::{synthetic, Demand, Trace};

/// The four simulated architectures, in canonical reporting order.
pub const FRAMEWORKS: [&str; 4] = ["megha", "sparrow", "eagle", "pigeon"];

/// Resolve a thread-count request: `0` means one thread per available
/// core.
pub fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// The number of OS threads one (framework, scenario) run actually
/// occupies: the scenario's requested shard count pushed through the
/// same clamps and fallbacks the run itself will hit. Pigeon has no
/// sharded port and always runs single-threaded; a zero-lookahead
/// network or a plan clamped to one shard delegates every framework to
/// the classic driver; Megha's plan cuts over its GM/LM federation,
/// Sparrow's and Eagle's over their schedulers x catalog nodes. The
/// sweep's thread-budget divisor uses this so scenarios that *record* a
/// fallback and run on one thread don't shrink the across-run fan-out.
fn effective_shards(framework: &str, sc: &Scenario) -> usize {
    let req = sc.shards.max(1);
    if req == 1 || sc.net.min_delay() == SimTime::ZERO {
        return 1; // PlanClamped / ZeroWindow: classic driver
    }
    match framework {
        "megha" => {
            let cfg = MeghaConfig::for_workers(sc.workers);
            ShardPlan::new(&cfg.spec, req).shards()
        }
        "sparrow" => {
            let cfg = SparrowConfig::for_workers(sc.workers);
            let n_nodes = sc
                .hetero
                .as_ref()
                .map_or(cfg.workers, |h| h.catalog(cfg.workers).n_nodes());
            ShardPlan::for_axes(cfg.n_schedulers, n_nodes, req).shards()
        }
        "eagle" => {
            let cfg = EagleConfig::for_workers(sc.workers);
            let n_nodes = sc
                .hetero
                .as_ref()
                .map_or(cfg.workers, |h| h.catalog(cfg.workers).n_nodes());
            ShardPlan::for_axes(cfg.n_schedulers, n_nodes, req).shards()
        }
        // pigeon (and anything unknown): no sharded port
        _ => 1,
    }
}

/// Apply `f` to every item on a pool of `threads` OS threads (0 = one
/// per core), returning results in input order. Work is distributed by
/// atomic index-stealing, so heterogeneous run times load-balance.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = effective_threads(threads).min(n);
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("item taken twice");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("worker exited before producing a result")
        })
        .collect()
}

/// Deterministic per-run seed: a SplitMix64-style mix of the sweep's
/// base seed, the scenario index, and the repetition index. Independent
/// of framework (paired traces) and of thread scheduling.
pub fn run_seed(base: u64, scenario: u64, rep: u64) -> u64 {
    let mut z = base
        ^ scenario.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ rep.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Which synthetic workload generator a scenario draws from.
#[derive(Clone, Debug)]
pub enum WorkloadKind {
    /// Heavy-tailed Yahoo-like trace (§4.1).
    Yahoo,
    /// Google-like sub-trace (§4.1).
    Google,
    /// The paper's synthetic workload: jobs of `tasks_per_job` × 1 s tasks.
    Fixed { tasks_per_job: usize },
}

impl WorkloadKind {
    pub fn parse(s: &str, tasks_per_job: usize) -> Option<WorkloadKind> {
        match s {
            "yahoo" => Some(WorkloadKind::Yahoo),
            "google" => Some(WorkloadKind::Google),
            "fixed" => Some(WorkloadKind::Fixed { tasks_per_job }),
            _ => None,
        }
    }
}

/// Heterogeneity axis of a scenario: which catalog profile every
/// framework's DC is built from, how scarce its scarce resource is, and
/// which demand a fraction of the trace's jobs carry.
///
/// Each framework builds the profile over its *own* worker count (they
/// round DC sizes differently), so the comparable quantity is the
/// scarcity fraction, not absolute slot ids; the trace (and therefore
/// the constrained job set) is shared verbatim across frameworks, as
/// always.
#[derive(Clone, Debug)]
pub struct HeteroSpec {
    /// Catalog profile name (see [`NodeCatalog::profile`]).
    pub profile: String,
    /// Profile scarcity knob (e.g. GPU slot fraction).
    pub scarcity: f64,
    /// Fraction of jobs carrying `demand`.
    pub constrained_frac: f64,
    pub demand: Demand,
}

impl HeteroSpec {
    /// Build this spec's catalog for a DC of `workers` slots.
    pub fn catalog(&self, workers: usize) -> NodeCatalog {
        NodeCatalog::profile(&self.profile, workers, self.scarcity).unwrap_or_else(|| {
            panic!(
                "unknown hetero profile '{}' (available: {})",
                self.profile,
                NodeCatalog::profile_names().join(", ")
            )
        })
    }
}

/// One cell of the sweep grid: a DC size, an offered load, a workload
/// shape, a network model (constant vs jittered), optional GM failure
/// injection (Megha only; §3.5), and an optional heterogeneity axis.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub workload: WorkloadKind,
    pub workers: usize,
    pub jobs: usize,
    pub load: f64,
    pub net: NetModel,
    /// Fail GM 0 at this many simulated seconds (Megha runs only).
    pub gm_fail_at: Option<f64>,
    /// Heterogeneous catalog + constrained jobs (None = homogeneous).
    pub hetero: Option<HeteroSpec>,
    /// Route bitmap queries through the occupancy index (`true`, the
    /// default everywhere). `false` selects the flat `naive_*` scans —
    /// the CLI `--no-index` debug mode and the bit-identity sweep
    /// goldens in `tests/index_oracle.rs`.
    pub use_index: bool,
    /// Execution shards per run (`SimParams::shards`): 1 = the classic
    /// sequential driver; N > 1 runs Megha's, Sparrow's, or Eagle's
    /// event loop on N threads (Pigeon falls back to 1, recorded on
    /// [`RunOutcome::shard_fallback`]). The sweep divides its across-run
    /// fan-out by the *effective* post-fallback shard counts, so total
    /// threads stay within the core budget without undersubscribing for
    /// falling-back runs.
    pub shards: usize,
    /// Idle-epoch fast-forward in the sharded driver
    /// (`SimParams::fast_forward`, default on); `false` selects the
    /// dense epoch grid — the CLI `--no-fast-forward` debug mode and
    /// the on/off identity golden in `tests/shard_identity.rs`.
    pub fast_forward: bool,
    /// Flight recorder (`SimParams::flight`, default off; CLI
    /// `--flight`): record per-decision event logs and surface staleness
    /// percentiles in the sweep's flight columns. Inert — the simulated
    /// schedule is bit-identical either way
    /// (`tests/driver_invariants.rs`).
    pub flight: bool,
    /// Fault-injection axes ([`FaultSpec`]): node churn, correlated rack
    /// outages, and the degraded-network window. Compiled per run into a
    /// [`FaultPlan`] against each framework's *own* catalog with the
    /// run's seed, so the schedule of faults is deterministic and paired
    /// across seeds (not across frameworks — they round DC sizes
    /// differently). `None` (and the inert default spec) runs
    /// bit-identical to a fault-free scenario.
    pub fault: Option<FaultSpec>,
}

impl Scenario {
    /// This scenario with the occupancy index toggled (see
    /// [`use_index`](Scenario::use_index)).
    pub fn with_index(mut self, on: bool) -> Scenario {
        self.use_index = on;
        self
    }

    /// This scenario with `n` execution shards per run (see
    /// [`shards`](Scenario::shards)).
    pub fn with_shards(mut self, n: usize) -> Scenario {
        self.shards = n.max(1);
        self
    }

    /// This scenario with the flight recorder toggled (see
    /// [`flight`](Scenario::flight)).
    pub fn with_flight(mut self, on: bool) -> Scenario {
        self.flight = on;
        self
    }

    /// A CI-sized rendition of this scenario: ~10x fewer workers and
    /// jobs (floored so tiny cells stay meaningful), same everything
    /// else — the CLI `--smoke` flag, e.g.
    /// `sweep --preset scale100 --smoke`.
    pub fn smoke(mut self) -> Scenario {
        self.workers = (self.workers / 10).max(600);
        self.jobs = (self.jobs / 10).max(60);
        self.name.push_str("-smoke");
        self
    }

    pub fn make_trace(&self, seed: u64) -> Trace {
        let trace = match self.workload {
            WorkloadKind::Yahoo => synthetic::yahoo_like(self.jobs, self.workers, self.load, seed),
            WorkloadKind::Google => {
                synthetic::google_like(self.jobs, self.workers, self.load, seed)
            }
            WorkloadKind::Fixed { tasks_per_job } => synthetic::synthetic_fixed(
                tasks_per_job,
                self.jobs,
                1.0,
                self.load,
                self.workers,
                seed,
            ),
        };
        match &self.hetero {
            Some(h) if h.constrained_frac > 0.0 => apply_constraints(
                trace,
                h.constrained_frac,
                h.demand.clone(),
                seed ^ CONSTRAIN_SEED,
            ),
            _ => trace,
        }
    }
}

/// Preset names accepted by [`preset`] (surfaced by `--help` and by the
/// unknown-preset error).
pub fn preset_names() -> &'static [&'static str] {
    &["scale10", "scale100", "hetero", "gang", "churn"]
}

/// Named scenario presets.
///
/// * `scale10` — the ISSUE-2 trace-replay target: the fig3a Yahoo smoke
///   shape at 10× jobs and 10× workers, the grid the hot-path overhaul
///   (bucketed queue, pooled payloads, delta snapshots) exists to make
///   routine.
/// * `scale100` — the ISSUE-6 sharded-execution target: the same Yahoo
///   shape at ~1M worker slots, run with 8 execution shards
///   (`Scenario::shards`; Megha, Sparrow, and Eagle shard their event
///   loops across that many threads, Pigeon falls back to sequential).
///   `--smoke` on the CLI shrinks it 10× for CI.
/// * `hetero` — the ISSUE-3 heterogeneity grid: attribute-scarcity ×
///   load on a bimodal-GPU catalog, plus one rack-tiered scenario. The
///   constrained fraction is calibrated so the *constrained sub-load*
///   (constrained work ÷ matching capacity) stays below 1 on the rich
///   cells and pushes toward saturation only on the scarce ones, while
///   the overall Eq.-6 offered load is untouched by construction.
/// * `gang` — the ISSUE-4 gang-placement grid: gang-size × load. Width-2
///   gangs target the bimodal profile's gpu pairs, width-4 gangs the
///   rack-tiered capacity-4 nodes; the constrained fraction is kept
///   modest so gangs contend for co-residency (the effect under test)
///   rather than for raw matching capacity.
/// * `churn` — the fault-injection grid (`Scenario::fault`): node churn
///   rate × drain fraction, one correlated rack-outage cell on the
///   rack-tiered catalog, and one degraded-network (partition +
///   straggler-tail) window. The recovery table (kills, re-runs,
///   time-to-redispatch percentiles) keys off these cells.
pub fn preset(name: &str, net: &NetModel) -> Option<Vec<Scenario>> {
    match name {
        "scale10" => Some(vec![Scenario {
            name: "scale10-yahoo-w6000".into(),
            workload: WorkloadKind::Yahoo,
            workers: 6_000,
            jobs: 1_500,
            load: 0.85,
            net: net.clone(),
            gm_fail_at: None,
            hetero: None,
            use_index: true,
            shards: 1,
            fast_forward: true,
            flight: false,
            fault: None,
        }]),
        "scale100" => Some(vec![Scenario {
            name: "scale100-yahoo-w1M".into(),
            workload: WorkloadKind::Yahoo,
            workers: 1_000_000,
            jobs: 25_000,
            load: 0.85,
            net: net.clone(),
            gm_fail_at: None,
            hetero: None,
            use_index: true,
            shards: 8, // clamps to min(n_gm, n_lm) = 8 at this size
            fast_forward: true,
            flight: false,
            fault: None,
        }]),
        "hetero" => {
            let gpu = |scarcity: f64, frac: f64| HeteroSpec {
                profile: "bimodal-gpu".into(),
                scarcity,
                constrained_frac: frac,
                demand: Demand::attrs(&["gpu"]),
            };
            let cell = |tag: &str, load: f64, h: HeteroSpec| Scenario {
                name: format!("hetero-{tag}-l{load:.2}"),
                workload: WorkloadKind::Yahoo,
                workers: 600,
                jobs: 200,
                load,
                net: net.clone(),
                gm_fail_at: None,
                hetero: Some(h),
                use_index: true,
                shards: 1,
                fast_forward: true,
                flight: false,
                fault: None,
            };
            Some(vec![
                // scarce: ~6% GPU slots, ~5% of jobs demand them
                cell("gpu-scarce", 0.5, gpu(0.0625, 0.05)),
                cell("gpu-scarce", 0.85, gpu(0.0625, 0.05)),
                // rich: ~25% GPU slots, 15% of jobs demand them
                cell("gpu-rich", 0.5, gpu(0.25, 0.15)),
                cell("gpu-rich", 0.85, gpu(0.25, 0.15)),
                // storage tiers: nvme racks at 1-in-4, 10% of jobs pinned
                cell(
                    "rack-nvme",
                    0.7,
                    HeteroSpec {
                        profile: "rack-tiered".into(),
                        scarcity: 0.25,
                        constrained_frac: 0.1,
                        demand: Demand::attrs(&["nvme"]),
                    },
                ),
            ])
        }
        "gang" => {
            let cell = |tag: &str, load: f64, h: HeteroSpec| Scenario {
                name: format!("gang-{tag}-l{load:.2}"),
                workload: WorkloadKind::Yahoo,
                workers: 600,
                jobs: 200,
                load,
                net: net.clone(),
                gm_fail_at: None,
                hetero: Some(h),
                use_index: true,
                shards: 1,
                fast_forward: true,
                flight: false,
                fault: None,
            };
            let gang2 = || HeteroSpec {
                profile: "bimodal-gpu".into(),
                scarcity: 0.25,
                constrained_frac: 0.15,
                demand: Demand::new(2, vec!["gpu".into()]),
            };
            let gang4 = || HeteroSpec {
                profile: "rack-tiered".into(),
                scarcity: 0.25,
                constrained_frac: 0.1,
                demand: Demand::new(4, vec![]),
            };
            Some(vec![
                // width-2 gangs on gpu pairs (capacity-skew axis)
                cell("g2-gpu", 0.5, gang2()),
                cell("g2-gpu", 0.85, gang2()),
                // width-4 gangs on rack-end big-mem nodes
                cell("g4-big", 0.5, gang4()),
                cell("g4-big", 0.85, gang4()),
            ])
        }
        "churn" => {
            let cell = |tag: &str, load: f64, h: Option<HeteroSpec>, fs: FaultSpec| Scenario {
                name: format!("churn-{tag}-l{load:.2}"),
                workload: WorkloadKind::Yahoo,
                workers: 600,
                jobs: 200,
                load,
                net: net.clone(),
                gm_fail_at: None,
                hetero: h,
                use_index: true,
                shards: 1,
                fast_forward: true,
                flight: false,
                fault: Some(fs),
            };
            let churn = |per_khour: f64, downtime_s: f64, drain_frac: f64| FaultSpec {
                churn_per_khour: per_khour,
                downtime_s,
                drain_frac,
                ..FaultSpec::default()
            };
            Some(vec![
                // churn-rate axis: crashes dominate, nodes heal in 30 s
                cell("light", 0.7, None, churn(60.0, 30.0, 0.25)),
                cell("heavy", 0.7, None, churn(240.0, 30.0, 0.25)),
                // pure drains: no work is ever lost, only capacity parks
                cell("drain", 0.7, None, churn(120.0, 30.0, 1.0)),
                // crash churn under saturation pressure
                cell("kill", 0.85, None, churn(120.0, 30.0, 0.0)),
                // correlated rack outages on the rack-tiered catalog
                cell(
                    "rack",
                    0.7,
                    Some(HeteroSpec {
                        profile: "rack-tiered".into(),
                        scarcity: 0.25,
                        constrained_frac: 0.0,
                        demand: Demand::attrs(&["nvme"]),
                    }),
                    FaultSpec {
                        rack_outages: 2,
                        downtime_s: 45.0,
                        ..FaultSpec::default()
                    },
                ),
                // partition-ish window: delays x8 with heavy-tail
                // stragglers, plus light churn underneath
                cell(
                    "degrade",
                    0.7,
                    None,
                    FaultSpec {
                        churn_per_khour: 60.0,
                        downtime_s: 30.0,
                        drain_frac: 0.25,
                        degrade: Some(NetDegrade {
                            from_s: 20.0,
                            until_s: 60.0,
                            factor: 8,
                            tail_ppm: 2000,
                            tail_factor: 40,
                        }),
                        ..FaultSpec::default()
                    },
                ),
            ])
        }
        _ => None,
    }
}

/// Build the `workers × loads` scenario grid for one workload/net
/// choice; `hetero`, when given, applies to every cell.
#[allow(clippy::too_many_arguments)]
pub fn scenario_grid(
    workload: &WorkloadKind,
    workers_list: &[usize],
    loads: &[f64],
    jobs: usize,
    net: &NetModel,
    gm_fail_at: Option<f64>,
    hetero: Option<&HeteroSpec>,
) -> Vec<Scenario> {
    let kind = match workload {
        WorkloadKind::Yahoo => "yahoo",
        WorkloadKind::Google => "google",
        WorkloadKind::Fixed { .. } => "fixed",
    };
    let mut out = Vec::new();
    for &workers in workers_list {
        for &load in loads {
            out.push(Scenario {
                name: format!("{kind}-w{workers}-l{load:.2}"),
                workload: workload.clone(),
                workers,
                jobs,
                load,
                net: net.clone(),
                gm_fail_at,
                hetero: hetero.cloned(),
                use_index: true,
                shards: 1,
                fast_forward: true,
                flight: false,
                fault: None,
            });
        }
    }
    out
}

/// Compile a scenario's fault axes for one framework's run: the
/// degraded-network overlay wraps the run's net model, and the churn /
/// rack-outage axes compile to a deterministic [`FaultPlan`] against the
/// framework's own catalog with the run's seed.
fn apply_fault(net: &mut NetModel, plan_slot: &mut Option<FaultPlan>, fs: &FaultSpec, catalog: &NodeCatalog, seed: u64) {
    if let Some(d) = &fs.degrade {
        *net = d.wrap(net.clone());
    }
    let plan = FaultPlan::compile(fs, catalog, seed);
    if !plan.is_empty() {
        *plan_slot = Some(plan);
    }
}

/// The one dispatch table from framework name to simulation: paper-shaped
/// config for `workers`, with the run's seed, an explicit network model,
/// optional GM failure injection (Megha only; the other frameworks have
/// no GM — the request is recorded on
/// [`RunOutcome::gm_fail_ignored`] instead of silently dropped), an
/// optional heterogeneity spec (each framework builds the catalog
/// over its own DC size), the occupancy-index routing flag, the
/// execution-shard count (Megha, Sparrow, and Eagle shard; Pigeon runs
/// the sequential driver and records
/// [`ShardFallback::Unsupported`] when shards were requested), the
/// idle-epoch fast-forward toggle, the flight-recorder toggle, and the
/// optional fault-injection axes (compiled per framework via
/// [`FaultPlan::compile`]).
/// `fig3::run_framework`, [`run_one`] and the cross-scheduler tests all
/// route through here.
#[allow(clippy::too_many_arguments)]
pub fn run_framework_hetero(
    framework: &str,
    workers: usize,
    seed: u64,
    net: &NetModel,
    gm_fail_at: Option<f64>,
    hetero: Option<&HeteroSpec>,
    use_index: bool,
    shards: usize,
    fast_forward: bool,
    flight: bool,
    fault: Option<&FaultSpec>,
    trace: &Trace,
) -> RunOutcome {
    match framework {
        "megha" => {
            let mut cfg = MeghaConfig::for_workers(workers);
            cfg.sim.seed = seed;
            cfg.sim.net = net.clone();
            cfg.sim.use_index = use_index;
            cfg.sim.shards = shards.max(1);
            cfg.sim.fast_forward = fast_forward;
            cfg.sim.flight = flight;
            if let Some(h) = hetero {
                cfg.catalog = h.catalog(cfg.spec.n_workers());
            }
            if let Some(fs) = fault {
                apply_fault(&mut cfg.sim.net, &mut cfg.sim.fault, fs, &cfg.catalog, seed);
            }
            let failure = gm_fail_at.map(|at| FailurePlan {
                at: SimTime::from_secs(at),
                gm: 0,
            });
            if cfg.sim.shards > 1 {
                sched::megha::simulate_sharded(&cfg, trace, failure)
            } else {
                sched::megha::simulate_with(&cfg, trace, &mut RustMatchEngine, failure)
            }
        }
        "sparrow" => {
            let mut cfg = SparrowConfig::for_workers(workers);
            cfg.sim.seed = seed;
            cfg.sim.net = net.clone();
            cfg.sim.use_index = use_index;
            cfg.sim.shards = shards.max(1);
            cfg.sim.fast_forward = fast_forward;
            cfg.sim.flight = flight;
            if let Some(h) = hetero {
                cfg.catalog = h.catalog(cfg.workers);
            }
            if let Some(fs) = fault {
                apply_fault(&mut cfg.sim.net, &mut cfg.sim.fault, fs, &cfg.catalog, seed);
            }
            let mut out = if cfg.sim.shards > 1 {
                sched::sparrow_sharded::simulate_sharded(&cfg, trace)
            } else {
                sched::sparrow::simulate(&cfg, trace)
            };
            out.gm_fail_ignored = gm_fail_at;
            out
        }
        "eagle" => {
            let mut cfg = EagleConfig::for_workers(workers);
            cfg.sim.seed = seed;
            cfg.sim.net = net.clone();
            cfg.sim.use_index = use_index;
            cfg.sim.shards = shards.max(1);
            cfg.sim.fast_forward = fast_forward;
            cfg.sim.flight = flight;
            if let Some(h) = hetero {
                cfg.catalog = h.catalog(cfg.workers);
            }
            if let Some(fs) = fault {
                apply_fault(&mut cfg.sim.net, &mut cfg.sim.fault, fs, &cfg.catalog, seed);
            }
            let mut out = if cfg.sim.shards > 1 {
                sched::eagle_sharded::simulate_sharded(&cfg, trace)
            } else {
                sched::eagle::simulate(&cfg, trace)
            };
            out.gm_fail_ignored = gm_fail_at;
            out
        }
        "pigeon" => {
            let mut cfg = PigeonConfig::for_workers(workers);
            cfg.sim.seed = seed;
            cfg.sim.net = net.clone();
            cfg.sim.use_index = use_index;
            cfg.sim.flight = flight;
            if let Some(h) = hetero {
                cfg.catalog = h.catalog(cfg.workers);
            }
            if let Some(fs) = fault {
                apply_fault(&mut cfg.sim.net, &mut cfg.sim.fault, fs, &cfg.catalog, seed);
            }
            let mut out = sched::pigeon::simulate(&cfg, trace);
            if shards > 1 {
                out.shard_fallback = Some(ShardFallback::Unsupported);
                crate::obs::flight::record_fallback(&mut out);
            }
            out.gm_fail_ignored = gm_fail_at;
            out
        }
        other => panic!("unknown framework '{other}'"),
    }
}

/// [`run_framework_hetero`] without a heterogeneity spec.
pub fn run_framework_with(
    framework: &str,
    workers: usize,
    seed: u64,
    net: &NetModel,
    gm_fail_at: Option<f64>,
    trace: &Trace,
) -> RunOutcome {
    run_framework_hetero(
        framework, workers, seed, net, gm_fail_at, None, true, 1, true, false, None, trace,
    )
}

/// [`run_framework_with`] on the paper-default network model.
pub fn run_framework(framework: &str, workers: usize, seed: u64, trace: &Trace) -> RunOutcome {
    run_framework_with(framework, workers, seed, &NetModel::paper_default(), None, trace)
}

/// Run one (framework, scenario, seed) cell through the unified driver.
pub fn run_one(framework: &str, sc: &Scenario, seed: u64) -> RunOutcome {
    let trace = sc.make_trace(seed);
    run_framework_hetero(
        framework,
        sc.workers,
        seed,
        &sc.net,
        sc.gm_fail_at,
        sc.hetero.as_ref(),
        sc.use_index,
        sc.shards,
        sc.fast_forward,
        sc.flight,
        sc.fault.as_ref(),
        &trace,
    )
}

/// The full sweep request.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub frameworks: Vec<String>,
    pub scenarios: Vec<Scenario>,
    /// Repetitions per cell (seed indices 0..seeds).
    pub seeds: u64,
    pub base_seed: u64,
    /// OS threads (0 = one per core).
    pub threads: usize,
}

/// One completed run of the sweep.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub framework: String,
    pub scenario: usize,
    pub rep: u64,
    pub seed: u64,
    pub summary: DelaySummary,
    /// Eq. 2 delays of *constrained* jobs only (n = 0 when the scenario
    /// has no heterogeneity axis).
    pub constrained: DelaySummary,
    /// Per-job `constraint_wait` percentiles (constrained jobs only).
    pub constraint_wait: DelaySummary,
    pub constraint_rejections: u64,
    /// Eq. 2 delays of *gang* jobs only (n = 0 when no job has
    /// `Demand::slots > 1`).
    pub gang: DelaySummary,
    /// Per-job `gang_wait` percentiles (gang jobs only).
    pub gang_wait: DelaySummary,
    pub gang_rejections: u64,
    pub inconsistency_ratio: f64,
    pub messages: u64,
    pub makespan_s: f64,
    /// Simulation events the run processed (deterministic).
    pub events: u64,
    /// Execution shards the run actually used ([`RunOutcome::shards`];
    /// 1 = sequential driver, which is every baseline).
    pub shards: u32,
    /// Why a shards > 1 request fell back to the sequential driver
    /// (`None` when sharding was honored or never requested).
    pub shard_fallback: Option<ShardFallback>,
    /// Flight-recorder aggregates ([`RunOutcome::flight`]; `None` when
    /// the scenario's [`Scenario::flight`] axis is off).
    pub flight: Option<FlightStats>,
    /// Recovery SLOs ([`RunOutcome`] fault accounting; all zero when the
    /// scenario's [`Scenario::fault`] axis is off or inert).
    pub tasks_killed: u64,
    pub tasks_rerun: u64,
    /// Task-seconds of execution progress destroyed by kills.
    pub work_lost_s: f64,
    /// Time-to-redispatch percentiles over the run's kill→re-commit
    /// pairs ([`RunOutcome::redispatch_summary`]).
    pub redispatch: DelaySummary,
    /// The run requested `gm_fail_at` of a GM-less framework
    /// ([`RunOutcome::gm_fail_ignored`]).
    pub gm_fail_ignored: Option<f64>,
    /// Wall-clock of the event loop only ([`RunOutcome::sim_wall_s`]) —
    /// the events/s denominator, excluding scheduler construction and
    /// summarization.
    pub sim_wall_s: f64,
    /// Wall-clock of the whole run on its worker thread (construction +
    /// event loop + summaries); feeds the sweep's cpu_s/speedup report.
    pub wall_s: f64,
}

impl RunRecord {
    /// Event-loop throughput of this run (events per host second),
    /// same definition as [`RunOutcome::events_per_sec`].
    pub fn events_per_sec(&self) -> f64 {
        if self.sim_wall_s > 0.0 {
            self.events as f64 / self.sim_wall_s
        } else {
            0.0
        }
    }
}

/// All records plus timing. `cpu_s` is the sum of per-run simulation
/// wall times and `wall_s` the parallel elapsed time of the *run phase
/// only* (trace synthesis is timed separately as `gen_s`, so the two
/// sides of the speedup ratio measure the same work). `cpu_s / wall_s`
/// estimates the speedup over running the same cells sequentially — an
/// *upper bound*, since concurrent runs contend for cores/caches and so
/// each run's measured time is itself inflated versus a solo run. For
/// an honest baseline, re-run the identical sweep with `threads: 1`
/// (results are bit-identical) and compare the two `wall_s` values.
pub struct SweepResult {
    pub records: Vec<RunRecord>,
    /// Elapsed wall-clock of the simulation phase.
    pub wall_s: f64,
    /// Elapsed wall-clock of (parallel) trace generation.
    pub gen_s: f64,
    pub cpu_s: f64,
    pub threads: usize,
}

impl SweepResult {
    /// Estimated parallel speedup (see the struct docs for its bias).
    pub fn speedup(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.cpu_s / self.wall_s
        } else {
            0.0
        }
    }
}

/// Execute every `(framework, scenario, rep)` cell in parallel.
///
/// Traces are generated once per (scenario, rep) — all frameworks share
/// the same trace by construction ([`run_seed`] ignores the framework),
/// so regenerating per run would only quadruple the workload-synthesis
/// cost for byte-identical inputs.
pub fn run_sweep(spec: &SweepSpec) -> SweepResult {
    let n_rep = spec.seeds as usize;
    let mut cell_keys: Vec<(usize, u64)> = Vec::new();
    for si in 0..spec.scenarios.len() {
        for rep in 0..spec.seeds {
            cell_keys.push((si, rep));
        }
    }
    let mut keys: Vec<(usize, usize, u64)> = Vec::new();
    for fi in 0..spec.frameworks.len() {
        for &(si, rep) in &cell_keys {
            keys.push((fi, si, rep));
        }
    }
    let budget = effective_threads(spec.threads).min(keys.len().max(1));
    let t_gen = Instant::now();
    let traces: Vec<Trace> = parallel_map(cell_keys, budget, |(si, rep)| {
        spec.scenarios[si].make_trace(run_seed(spec.base_seed, si as u64, rep))
    });
    let gen_s = t_gen.elapsed().as_secs_f64();
    // A run with `shards` execution shards occupies that many OS threads
    // on its own; divide the across-run fan-out by the widest (framework,
    // scenario) cell so the sweep's total thread count stays within the
    // core budget rather than oversubscribing shards x runs threads.
    // "Widest" means *effective* shards after the same clamps and
    // fallbacks the run itself will hit — a grid of falling-back
    // frameworks (e.g. Pigeon at shards = 8) runs single-threaded and
    // must not shrink the across-run fan-out 8x for nothing.
    let max_shards = spec
        .scenarios
        .iter()
        .map(|sc| {
            spec.frameworks
                .iter()
                .map(|f| effective_shards(f, sc))
                .max()
                .unwrap_or(1)
        })
        .max()
        .unwrap_or(1);
    let threads = (budget / max_shards).max(1);
    let t0 = Instant::now();
    let records = parallel_map(keys, threads, |(fi, si, rep)| {
        let framework = &spec.frameworks[fi];
        let sc = &spec.scenarios[si];
        let seed = run_seed(spec.base_seed, si as u64, rep);
        let trace = &traces[si * n_rep + rep as usize];
        let r0 = Instant::now();
        let out = run_framework_hetero(
            framework,
            sc.workers,
            seed,
            &sc.net,
            sc.gm_fail_at,
            sc.hetero.as_ref(),
            sc.use_index,
            sc.shards,
            sc.fast_forward,
            sc.flight,
            sc.fault.as_ref(),
            trace,
        );
        RunRecord {
            framework: framework.clone(),
            scenario: si,
            rep,
            seed,
            summary: summarize_jobs(&out.jobs),
            constrained: summarize_constrained(&out.jobs),
            constraint_wait: summarize_constraint_wait(&out.jobs),
            constraint_rejections: out.constraint_rejections,
            gang: summarize_gang(&out.jobs),
            gang_wait: summarize_gang_wait(&out.jobs),
            gang_rejections: out.gang_rejections,
            inconsistency_ratio: out.inconsistency_ratio(),
            messages: out.messages,
            makespan_s: out.makespan.as_secs(),
            events: out.events,
            shards: out.shards,
            shard_fallback: out.shard_fallback,
            flight: out.flight,
            tasks_killed: out.tasks_killed,
            tasks_rerun: out.tasks_rerun,
            work_lost_s: out.work_lost_s,
            redispatch: out.redispatch_summary(),
            gm_fail_ignored: out.gm_fail_ignored,
            sim_wall_s: out.sim_wall_s,
            wall_s: r0.elapsed().as_secs_f64(),
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let cpu_s = records.iter().map(|r| r.wall_s).sum();
    SweepResult {
        records,
        wall_s,
        gen_s,
        cpu_s,
        threads,
    }
}

/// Per-(scenario, framework) aggregate over seeds: percentile table of
/// the per-run delay summaries.
#[derive(Clone, Debug)]
pub struct AggRow {
    pub framework: String,
    pub scenario: usize,
    pub runs: usize,
    /// Median across seeds of the per-run median delay.
    pub median_p50: f64,
    pub median_min: f64,
    pub median_max: f64,
    /// Median / 95th percentile across seeds of the per-run p95 delay.
    pub p95_p50: f64,
    pub p95_p95: f64,
    /// Mean of per-run mean delays.
    pub mean: f64,
    pub inconsistency: f64,
    /// Constrained jobs per run (0 ⇒ homogeneous cell; the constraint
    /// columns below are then all zero).
    pub constrained_n: usize,
    /// Median across seeds of the per-run constrained-job p99 delay.
    pub constrained_p99: f64,
    /// Median across seeds of the per-run `constraint_wait` p50 / p99.
    pub cwait_p50: f64,
    pub cwait_p99: f64,
    /// Gang jobs per run (0 ⇒ no gang demands in the cell).
    pub gang_n: usize,
    /// Median across seeds of the per-run gang-job p99 delay.
    pub gang_p99: f64,
    /// Median across seeds of the per-run `gang_wait` p50 / p99.
    pub gwait_p50: f64,
    pub gwait_p99: f64,
    /// Mean gang rejections per run.
    pub gang_rejections: f64,
    /// Mean event-loop throughput (events/s) over the cell's runs, so
    /// harness regressions are visible in normal sweep output.
    pub events_per_sec: f64,
    /// Execution shards the cell's runs used (max over runs; 1 =
    /// sequential driver).
    pub shards: u32,
    /// Runs in this cell that carried flight-recorder stats (0 ⇒ the
    /// scenario's flight axis was off; the columns below are then zero).
    pub flight_n: usize,
    /// Median across runs of the per-run recorded-event count.
    pub flight_events: f64,
    /// Median across runs of the per-run p50 / p99 staleness-at-match
    /// (µs of GM-view age behind the matched LM's last refresh).
    pub stale_p50_us: f64,
    pub stale_p99_us: f64,
    /// Median across runs of the per-run p99 invalidation-chain length
    /// (LM-invalidations one (GM, job) pair accumulated).
    pub chain_p99: f64,
    /// Mean tasks killed / re-run per run (0 ⇒ the cell's fault axis is
    /// off or never hit a running task; the recovery columns below are
    /// then zero too).
    pub killed: f64,
    pub rerun: f64,
    /// Mean task-seconds of work destroyed per run.
    pub work_lost_s: f64,
    /// Median across runs of the per-run time-to-redispatch p50 / p99.
    pub redispatch_p50: f64,
    pub redispatch_p99: f64,
}

pub fn aggregate(spec: &SweepSpec, records: &[RunRecord]) -> Vec<AggRow> {
    // one grouping pass (records from foreign specs are ignored), then
    // rows emitted in spec order: scenario-major, framework-minor
    let nf = spec.frameworks.len();
    let mut groups: Vec<Vec<&RunRecord>> = vec![Vec::new(); spec.scenarios.len() * nf];
    for r in records {
        if r.scenario >= spec.scenarios.len() {
            continue;
        }
        if let Some(fi) = spec.frameworks.iter().position(|f| f == &r.framework) {
            groups[r.scenario * nf + fi].push(r);
        }
    }
    let mut rows = Vec::new();
    for si in 0..spec.scenarios.len() {
        for (fi, fw) in spec.frameworks.iter().enumerate() {
            let rs = &groups[si * nf + fi];
            if rs.is_empty() {
                continue;
            }
            let medians: Vec<f64> = rs.iter().map(|r| r.summary.median).collect();
            let p95s: Vec<f64> = rs.iter().map(|r| r.summary.p95).collect();
            let means: Vec<f64> = rs.iter().map(|r| r.summary.mean).collect();
            let incons: Vec<f64> = rs.iter().map(|r| r.inconsistency_ratio).collect();
            let eps: Vec<f64> = rs.iter().map(|r| r.events_per_sec()).collect();
            let con_p99s: Vec<f64> = rs.iter().map(|r| r.constrained.p99).collect();
            let cw_p50s: Vec<f64> = rs.iter().map(|r| r.constraint_wait.median).collect();
            let cw_p99s: Vec<f64> = rs.iter().map(|r| r.constraint_wait.p99).collect();
            let g_p99s: Vec<f64> = rs.iter().map(|r| r.gang.p99).collect();
            let gw_p50s: Vec<f64> = rs.iter().map(|r| r.gang_wait.median).collect();
            let gw_p99s: Vec<f64> = rs.iter().map(|r| r.gang_wait.p99).collect();
            let g_rejs: Vec<f64> = rs.iter().map(|r| r.gang_rejections as f64).collect();
            let flights: Vec<FlightStats> = rs.iter().filter_map(|r| r.flight).collect();
            let f_events: Vec<f64> = flights.iter().map(|f| f.events as f64).collect();
            let f_p50s: Vec<f64> = flights.iter().map(|f| f.stale_p50_us as f64).collect();
            let f_p99s: Vec<f64> = flights.iter().map(|f| f.stale_p99_us as f64).collect();
            let f_chains: Vec<f64> = flights.iter().map(|f| f.chain_p99 as f64).collect();
            let killeds: Vec<f64> = rs.iter().map(|r| r.tasks_killed as f64).collect();
            let reruns: Vec<f64> = rs.iter().map(|r| r.tasks_rerun as f64).collect();
            let losts: Vec<f64> = rs.iter().map(|r| r.work_lost_s).collect();
            let rd_p50s: Vec<f64> = rs.iter().map(|r| r.redispatch.median).collect();
            let rd_p99s: Vec<f64> = rs.iter().map(|r| r.redispatch.p99).collect();
            rows.push(AggRow {
                framework: fw.clone(),
                scenario: si,
                runs: rs.len(),
                median_p50: percentile(&medians, 50.0),
                median_min: medians.iter().copied().fold(f64::INFINITY, f64::min),
                median_max: medians.iter().copied().fold(f64::NEG_INFINITY, f64::max),
                p95_p50: percentile(&p95s, 50.0),
                p95_p95: percentile(&p95s, 95.0),
                mean: mean(&means),
                inconsistency: mean(&incons),
                constrained_n: rs.iter().map(|r| r.constrained.n).max().unwrap_or(0),
                constrained_p99: percentile(&con_p99s, 50.0),
                cwait_p50: percentile(&cw_p50s, 50.0),
                cwait_p99: percentile(&cw_p99s, 50.0),
                gang_n: rs.iter().map(|r| r.gang.n).max().unwrap_or(0),
                gang_p99: percentile(&g_p99s, 50.0),
                gwait_p50: percentile(&gw_p50s, 50.0),
                gwait_p99: percentile(&gw_p99s, 50.0),
                gang_rejections: mean(&g_rejs),
                events_per_sec: mean(&eps),
                shards: rs.iter().map(|r| r.shards).max().unwrap_or(1),
                flight_n: flights.len(),
                flight_events: percentile(&f_events, 50.0),
                stale_p50_us: percentile(&f_p50s, 50.0),
                stale_p99_us: percentile(&f_p99s, 50.0),
                chain_p99: percentile(&f_chains, 50.0),
                killed: mean(&killeds),
                rerun: mean(&reruns),
                work_lost_s: mean(&losts),
                redispatch_p50: percentile(&rd_p50s, 50.0),
                redispatch_p99: percentile(&rd_p99s, 50.0),
            });
        }
    }
    rows
}

/// Print the aggregate percentile table plus the speedup line.
pub fn print_result(spec: &SweepSpec, result: &SweepResult) {
    println!(
        "\n=== sweep: {} framework(s) x {} scenario(s) x {} seed(s) = {} runs on {} threads ===",
        spec.frameworks.len(),
        spec.scenarios.len(),
        spec.seeds,
        result.records.len(),
        result.threads
    );
    // a GM-failure request against a GM-less framework is recorded per
    // run (RunOutcome::gm_fail_ignored); warn exactly once per framework
    // so the request is never silently dropped
    let mut gm_warned: Vec<&str> = Vec::new();
    for r in &result.records {
        if let Some(at) = r.gm_fail_ignored {
            if !gm_warned.contains(&r.framework.as_str()) {
                gm_warned.push(r.framework.as_str());
                eprintln!(
                    "warning: {} has no global manager; --gm-fail-at {at} was ignored",
                    r.framework
                );
            }
        }
    }
    // sharding fallbacks are recorded per run; surface each distinct
    // reason exactly once so a clamped `--shards` request is never silent
    let mut warned: Vec<(&str, ShardFallback)> = Vec::new();
    for r in &result.records {
        if let Some(fb) = r.shard_fallback {
            let key = (r.framework.as_str(), fb);
            if !warned.contains(&key) {
                warned.push(key);
                eprintln!(
                    "warning: {} ran unsharded in '{}': {}",
                    r.framework,
                    spec.scenarios[r.scenario].name,
                    fb.reason()
                );
            }
        }
    }
    println!(
        "{:<22} {:<9} {:>4} {:>10} {:>21} {:>10} {:>10} {:>10} {:>12} {:>11} {:>6}",
        "scenario",
        "framework",
        "runs",
        "med(s)",
        "med range",
        "p95(s)",
        "p95^95",
        "mean(s)",
        "incons/task",
        "events/s",
        "shards"
    );
    let rows = aggregate(spec, &result.records);
    for r in &rows {
        println!(
            "{:<22} {:<9} {:>4} {:>10.4} [{:>9.4},{:>9.4}] {:>10.3} {:>10.3} {:>10.3} {:>12.5} {:>11.0} {:>6}",
            spec.scenarios[r.scenario].name,
            r.framework,
            r.runs,
            r.median_p50,
            r.median_min,
            r.median_max,
            r.p95_p50,
            r.p95_p95,
            r.mean,
            r.inconsistency,
            r.events_per_sec,
            r.shards
        );
    }
    if rows.iter().any(|r| r.constrained_n > 0) {
        println!("\n--- constrained jobs (per-framework constraint_wait percentiles) ---");
        println!(
            "{:<22} {:<9} {:>6} {:>12} {:>13} {:>13}",
            "scenario", "framework", "jobs", "delay-p99(s)", "cwait-p50(s)", "cwait-p99(s)"
        );
        for r in rows.iter().filter(|r| r.constrained_n > 0) {
            println!(
                "{:<22} {:<9} {:>6} {:>12.3} {:>13.4} {:>13.3}",
                spec.scenarios[r.scenario].name,
                r.framework,
                r.constrained_n,
                r.constrained_p99,
                r.cwait_p50,
                r.cwait_p99
            );
        }
        println!();
    }
    if rows.iter().any(|r| r.gang_n > 0) {
        println!("\n--- gang jobs (multi-slot co-resident placement, per framework) ---");
        println!(
            "{:<22} {:<9} {:>6} {:>12} {:>13} {:>13} {:>11}",
            "scenario",
            "framework",
            "jobs",
            "delay-p99(s)",
            "gwait-p50(s)",
            "gwait-p99(s)",
            "gang-rej"
        );
        for r in rows.iter().filter(|r| r.gang_n > 0) {
            println!(
                "{:<22} {:<9} {:>6} {:>12.3} {:>13.4} {:>13.3} {:>11.1}",
                spec.scenarios[r.scenario].name,
                r.framework,
                r.gang_n,
                r.gang_p99,
                r.gwait_p50,
                r.gwait_p99,
                r.gang_rejections
            );
        }
        println!();
    }
    if rows.iter().any(|r| r.killed > 0.0) {
        println!("\n--- recovery (fault injection: kills, re-runs, time-to-redispatch) ---");
        println!(
            "{:<22} {:<9} {:>8} {:>8} {:>12} {:>13} {:>13}",
            "scenario", "framework", "killed", "rerun", "lost(task-s)", "redisp-p50(s)", "redisp-p99(s)"
        );
        for r in rows.iter().filter(|r| r.killed > 0.0) {
            println!(
                "{:<22} {:<9} {:>8.1} {:>8.1} {:>12.1} {:>13.4} {:>13.3}",
                spec.scenarios[r.scenario].name,
                r.framework,
                r.killed,
                r.rerun,
                r.work_lost_s,
                r.redispatch_p50,
                r.redispatch_p99
            );
        }
        println!();
    }
    if rows.iter().any(|r| r.flight_n > 0) {
        println!("\n--- flight recorder (staleness-at-match, invalidation chains) ---");
        println!(
            "{:<22} {:<9} {:>6} {:>10} {:>13} {:>13} {:>10}",
            "scenario", "framework", "runs", "events", "stale-p50(us)", "stale-p99(us)", "chain-p99"
        );
        for r in rows.iter().filter(|r| r.flight_n > 0) {
            println!(
                "{:<22} {:<9} {:>6} {:>10.0} {:>13.0} {:>13.0} {:>10.1}",
                spec.scenarios[r.scenario].name,
                r.framework,
                r.flight_n,
                r.flight_events,
                r.stale_p50_us,
                r.stale_p99_us,
                r.chain_p99
            );
        }
        println!();
    }
    println!(
        "trace-gen {:.2}s | run wall-clock {:.2}s | summed run time {:.2}s | \
         est. speedup {:.2}x ({} threads; rerun with --threads 1 for an exact \
         sequential baseline)",
        result.gen_s,
        result.wall_s,
        result.cpu_s,
        result.speedup(),
        result.threads
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(threads: usize) -> SweepSpec {
        SweepSpec {
            frameworks: vec!["megha".into(), "sparrow".into()],
            scenarios: scenario_grid(
                &WorkloadKind::Fixed { tasks_per_job: 10 },
                &[120],
                &[0.4, 0.8],
                12,
                &NetModel::paper_default(),
                None,
                None,
            ),
            seeds: 3,
            base_seed: 42,
            threads,
        }
    }

    #[test]
    fn run_seed_is_deterministic_and_decorrelated() {
        assert_eq!(run_seed(1, 2, 3), run_seed(1, 2, 3));
        let mut seen = std::collections::HashSet::new();
        for sc in 0..8u64 {
            for rep in 0..8u64 {
                assert!(seen.insert(run_seed(7, sc, rep)), "collision at {sc}/{rep}");
            }
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        let ys = parallel_map(xs.clone(), 4, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
        // single-threaded path agrees
        let zs = parallel_map(xs.clone(), 1, |x| x * 2);
        assert_eq!(ys, zs);
    }

    #[test]
    fn sweep_covers_the_grid() {
        let spec = tiny_spec(2);
        let res = run_sweep(&spec);
        assert_eq!(res.records.len(), 2 * 2 * 3);
        // paired seeding: same (scenario, rep) → same seed across frameworks
        for r in &res.records {
            assert_eq!(r.seed, run_seed(spec.base_seed, r.scenario as u64, r.rep));
            assert!(r.summary.n > 0, "empty summary for {}", r.framework);
            assert!(r.events > 0, "no events recorded for {}", r.framework);
        }
        let rows = aggregate(&spec, &res.records);
        assert_eq!(rows.len(), 2 * 2);
        assert!(rows.iter().all(|r| r.runs == 3));
    }

    #[test]
    fn sweep_results_independent_of_thread_count() {
        let a = run_sweep(&tiny_spec(1));
        let b = run_sweep(&tiny_spec(4));
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(b.records.iter()) {
            assert_eq!(x.framework, y.framework);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.makespan_s, y.makespan_s);
            assert_eq!(x.messages, y.messages);
            assert_eq!(x.events, y.events);
            assert_eq!(x.summary.median, y.summary.median);
            assert_eq!(x.summary.p95, y.summary.p95);
        }
    }

    #[test]
    fn scale10_preset_resolves() {
        let net = NetModel::paper_default();
        let scs = preset("scale10", &net).expect("scale10 preset");
        assert_eq!(scs.len(), 1);
        assert_eq!(scs[0].workers, 6_000);
        assert_eq!(scs[0].jobs, 1_500);
        assert!(preset("nope", &net).is_none());
        for name in preset_names() {
            assert!(preset(name, &net).is_some(), "preset '{name}' missing");
        }
    }

    #[test]
    fn scale100_preset_is_sharded_at_megascale() {
        let net = NetModel::paper_default();
        let scs = preset("scale100", &net).expect("scale100 preset");
        assert_eq!(scs.len(), 1);
        assert!(scs[0].workers >= 1_000_000, "~1M worker slots");
        assert_eq!(scs[0].shards, 8);
        // every other preset stays on the sequential driver
        for name in ["scale10", "hetero", "gang", "churn"] {
            for sc in preset(name, &net).unwrap() {
                assert_eq!(sc.shards, 1, "{}", sc.name);
            }
        }
    }

    #[test]
    fn sharded_scenario_runs_and_divides_thread_budget() {
        // sharded cells through the sweep front door: every ported
        // framework reports its shard count and the across-run pool is
        // divided by the effective width
        let sc = Scenario {
            name: "shard-tiny".into(),
            workload: WorkloadKind::Fixed { tasks_per_job: 8 },
            workers: 300,
            jobs: 20,
            load: 0.6,
            net: NetModel::paper_default(),
            gm_fail_at: None,
            hetero: None,
            use_index: true,
            shards: 2,
            fast_forward: true,
            flight: false,
            fault: None,
        };
        let spec = SweepSpec {
            frameworks: vec!["megha".into(), "sparrow".into()],
            scenarios: vec![sc],
            seeds: 2,
            base_seed: 9,
            threads: 4,
        };
        let res = run_sweep(&spec);
        assert_eq!(res.threads, 2, "4-thread budget / 2 shards");
        for r in &res.records {
            assert_eq!(r.shards, 2, "{}", r.framework);
        }
        let rows = aggregate(&spec, &res.records);
        assert!(rows.iter().any(|r| r.shards == 2));
    }

    #[test]
    fn fallback_only_grid_keeps_the_full_thread_budget() {
        // regression (ISSUE 9): the budget divisor must come from
        // *effective* shard counts. Pigeon has no sharded port — a
        // pigeon-only grid requesting 8 shards runs every cell on one
        // thread, so dividing the across-run fan-out by the requested 8
        // would undersubscribe the pool 8x for nothing.
        let sc = Scenario {
            name: "fallback-tiny".into(),
            workload: WorkloadKind::Fixed { tasks_per_job: 8 },
            workers: 200,
            jobs: 12,
            load: 0.6,
            net: NetModel::paper_default(),
            gm_fail_at: None,
            hetero: None,
            use_index: true,
            shards: 8,
            fast_forward: true,
            flight: false,
            fault: None,
        };
        let spec = SweepSpec {
            frameworks: vec!["pigeon".into()],
            scenarios: vec![sc],
            seeds: 4,
            base_seed: 21,
            threads: 4,
        };
        let res = run_sweep(&spec);
        assert_eq!(res.threads, 4, "fallback-only grid must not divide the budget");
        for r in &res.records {
            assert_eq!(r.shards, 1, "{}", r.framework);
        }
    }

    #[test]
    fn effective_shards_tracks_clamps_and_fallbacks() {
        let mut sc = Scenario {
            name: "eff".into(),
            workload: WorkloadKind::Fixed { tasks_per_job: 8 },
            workers: 300,
            jobs: 10,
            load: 0.5,
            net: NetModel::paper_default(),
            gm_fail_at: None,
            hetero: None,
            use_index: true,
            shards: 4,
            fast_forward: true,
            flight: false,
            fault: None,
        };
        // all three ported frameworks shard; pigeon never does. Megha's
        // plan cuts over its 3x3 GM/LM federation at this DC size, so a
        // 4-shard request clamps to 3.
        assert_eq!(effective_shards("megha", &sc), 3);
        assert_eq!(effective_shards("sparrow", &sc), 4);
        assert_eq!(effective_shards("eagle", &sc), 4);
        assert_eq!(effective_shards("pigeon", &sc), 1);
        // requesting more shards than scheduler-side entities clamps
        // (Sparrow and Eagle have 8 distributed schedulers)
        sc.shards = 64;
        assert_eq!(effective_shards("sparrow", &sc), 8);
        assert_eq!(effective_shards("eagle", &sc), 8);
        // a zero-lookahead network forces the classic driver everywhere
        sc.shards = 4;
        sc.net = NetModel::Jittered {
            base: SimTime::ZERO,
            jitter: SimTime::from_millis(1.0),
        };
        for f in FRAMEWORKS {
            assert_eq!(effective_shards(f, &sc), 1, "{f}");
        }
    }

    #[test]
    fn hetero_preset_resolves_and_constrains_traces() {
        let net = NetModel::paper_default();
        let scs = preset("hetero", &net).expect("hetero preset");
        assert!(scs.len() >= 4);
        for sc in &scs {
            let h = sc.hetero.as_ref().expect("hetero scenario");
            // profile resolves against any DC size the frameworks pick
            let cat = h.catalog(sc.workers);
            assert!(!cat.is_trivial());
            let trace = sc.make_trace(run_seed(1, 0, 0));
            let n = trace.jobs.iter().filter(|j| j.demand.is_some()).count();
            assert!(n > 0, "{}: no constrained jobs", sc.name);
            // offered load is untouched by constraint decoration (wide
            // tolerance: 200-job synthesis has sampling noise)
            assert!(
                (trace.offered_load(sc.workers) - sc.load).abs() < 0.3,
                "{}: load drifted",
                sc.name
            );
        }
    }

    #[test]
    fn gang_preset_resolves_and_decorates_traces() {
        let net = NetModel::paper_default();
        let scs = preset("gang", &net).expect("gang preset");
        assert_eq!(scs.len(), 4);
        for sc in &scs {
            let h = sc.hetero.as_ref().expect("gang scenario is heterogeneous");
            assert!(h.demand.slots > 1, "{}: not a gang demand", sc.name);
            let cat = h.catalog(sc.workers);
            assert!(!cat.is_trivial());
            // the demand must resolve as a gang against the profile
            let rd = cat.resolve(&h.demand).expect("gang demand resolves");
            assert!(rd.is_gang());
            assert!(cat.gangs_possible(0, cat.len(), &rd) > 0);
            let trace = sc.make_trace(run_seed(1, 0, 0));
            let n = trace
                .jobs
                .iter()
                .filter(|j| j.demand.as_ref().is_some_and(|d| d.slots > 1))
                .count();
            assert!(n > 0, "{}: no gang jobs", sc.name);
        }
    }

    #[test]
    fn gang_cells_run_all_frameworks() {
        // one tiny gang cell end-to-end per framework (the full preset
        // runs in CI via `sweep --preset gang`)
        let sc = Scenario {
            name: "gang-tiny".into(),
            workload: WorkloadKind::Fixed { tasks_per_job: 8 },
            workers: 192,
            jobs: 20,
            load: 0.6,
            net: NetModel::paper_default(),
            gm_fail_at: None,
            hetero: Some(HeteroSpec {
                profile: "bimodal-gpu".into(),
                scarcity: 0.25,
                constrained_frac: 0.4,
                demand: Demand::new(2, vec!["gpu".into()]),
            }),
            use_index: true,
            shards: 1,
            fast_forward: true,
            flight: false,
            fault: None,
        };
        for fw in FRAMEWORKS {
            let out = run_one(fw, &sc, 7);
            assert_eq!(out.jobs.len(), 20, "{fw} lost jobs");
            assert!(
                out.jobs.iter().any(|j| j.gang),
                "{fw}: no gang job records"
            );
        }
    }

    #[test]
    fn hetero_cells_run_all_frameworks() {
        // one tiny hetero cell end-to-end per framework (the full
        // preset runs in CI via `sweep --preset hetero`)
        let sc = Scenario {
            name: "hetero-tiny".into(),
            workload: WorkloadKind::Fixed { tasks_per_job: 10 },
            workers: 160,
            jobs: 24,
            load: 0.6,
            net: NetModel::paper_default(),
            gm_fail_at: None,
            hetero: Some(HeteroSpec {
                profile: "bimodal-gpu".into(),
                scarcity: 0.125,
                constrained_frac: 0.5,
                demand: Demand::attrs(&["gpu"]),
            }),
            use_index: true,
            shards: 1,
            fast_forward: true,
            flight: false,
            fault: None,
        };
        for fw in FRAMEWORKS {
            let out = run_one(fw, &sc, 3);
            assert_eq!(out.jobs.len(), 24, "{fw} lost jobs");
            assert!(
                out.jobs.iter().any(|j| j.constrained),
                "{fw}: no constrained job records"
            );
        }
    }

    #[test]
    fn fault_churn_preset_resolves() {
        let net = NetModel::paper_default();
        let scs = preset("churn", &net).expect("churn preset");
        assert!(scs.len() >= 5);
        for sc in &scs {
            let fs = sc.fault.as_ref().expect("churn scenario has a fault axis");
            assert!(!fs.is_inert(), "{}: inert fault spec", sc.name);
        }
        // churn cells compile to non-empty plans on the default catalog
        let fs = scs[0].fault.as_ref().unwrap();
        let plan = FaultPlan::compile(fs, &NodeCatalog::uniform(600), run_seed(1, 0, 0));
        assert!(!plan.is_empty());
        // the degrade cell carries a network window
        assert!(scs.iter().any(|sc| sc
            .fault
            .as_ref()
            .is_some_and(|f| f.degrade.is_some())));
    }

    #[test]
    fn fault_scenario_runs_all_frameworks_and_conserves_tasks() {
        // one faulted cell end-to-end per framework through the sweep
        // front door: killed work re-runs exactly once everywhere
        let sc = Scenario {
            name: "churn-tiny".into(),
            workload: WorkloadKind::Fixed { tasks_per_job: 12 },
            workers: 150,
            jobs: 30,
            load: 0.8,
            net: NetModel::paper_default(),
            gm_fail_at: None,
            hetero: None,
            use_index: true,
            shards: 1,
            fast_forward: true,
            flight: false,
            fault: Some(FaultSpec {
                churn_per_khour: 3000.0,
                downtime_s: 10.0,
                drain_frac: 0.0,
                horizon_s: 40.0,
                ..FaultSpec::default()
            }),
        };
        for fw in FRAMEWORKS {
            let out = run_one(fw, &sc, 7);
            assert_eq!(out.jobs.len(), 30, "{fw} lost jobs");
            assert_eq!(
                out.tasks,
                30 * 12 + out.tasks_killed,
                "{fw}: task conservation"
            );
            assert_eq!(out.tasks_rerun, out.tasks_killed, "{fw}");
        }
    }

    #[test]
    fn fault_gm_fail_request_recorded_for_gmless_frameworks() {
        // regression: `--gm-fail-at` against Sparrow/Eagle/Pigeon used
        // to be silently dropped; it must be recorded on the outcome
        let sc = Scenario {
            name: "gmfail-tiny".into(),
            workload: WorkloadKind::Fixed { tasks_per_job: 8 },
            workers: 100,
            jobs: 10,
            load: 0.6,
            net: NetModel::paper_default(),
            gm_fail_at: Some(2.0),
            hetero: None,
            use_index: true,
            shards: 1,
            fast_forward: true,
            flight: false,
            fault: None,
        };
        for fw in ["sparrow", "eagle", "pigeon"] {
            let out = run_one(fw, &sc, 5);
            assert_eq!(out.gm_fail_ignored, Some(2.0), "{fw}");
        }
        // Megha honors the request and must NOT record it as ignored
        let out = run_one("megha", &sc, 5);
        assert_eq!(out.gm_fail_ignored, None);
    }

    #[test]
    fn jittered_net_and_failure_scenarios_complete() {
        let sc = Scenario {
            name: "jitter-fail".into(),
            workload: WorkloadKind::Fixed { tasks_per_job: 8 },
            workers: 100,
            jobs: 10,
            load: 0.6,
            net: NetModel::Jittered {
                base: SimTime::from_millis(0.3),
                jitter: SimTime::from_millis(0.4),
            },
            gm_fail_at: Some(2.0),
            hetero: None,
            use_index: true,
            shards: 1,
            fast_forward: true,
            flight: false,
            fault: None,
        };
        for fw in FRAMEWORKS {
            let out = run_one(fw, &sc, 5);
            assert_eq!(out.jobs.len(), 10, "{fw} lost jobs");
        }
    }
}
