//! `key = value` config-file loader (flattened INI-style sections).
//!
//! ```text
//! # experiment config
//! [megha]
//! heartbeat_s = 5.0
//! max_batch = 64
//! ```
//! parses to keys `megha.heartbeat_s`, `megha.max_batch`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct ConfigFile {
    pub values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<ConfigFile> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            if let Some(sec) = line.strip_prefix('[') {
                let Some(sec) = sec.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                section = sec.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(ConfigFile { values })
    }

    pub fn load(path: &std::path::Path) -> Result<ConfigFile> {
        ConfigFile::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("config key '{key}': bad number '{v}'")),
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("config key '{key}': bad integer '{v}'")),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config key '{key}': bad bool '{v}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let c = ConfigFile::parse(
            "# top\nglobal = 1\n[megha]\nheartbeat_s = 5.0 # inline\nmax_batch = 64\n[sim]\nseed=7\n",
        )
        .unwrap();
        assert_eq!(c.get("global"), Some("1"));
        assert_eq!(c.f64("megha.heartbeat_s", 0.0).unwrap(), 5.0);
        assert_eq!(c.usize("megha.max_batch", 0).unwrap(), 64);
        assert_eq!(c.usize("sim.seed", 0).unwrap(), 7);
    }

    #[test]
    fn defaults_and_errors() {
        let c = ConfigFile::parse("x = notanumber\nb = yes\n").unwrap();
        assert_eq!(c.usize("missing", 3).unwrap(), 3);
        assert!(c.f64("x", 0.0).is_err());
        assert!(c.bool("b", false).unwrap());
        assert!(ConfigFile::parse("justkey\n").is_err());
        assert!(ConfigFile::parse("[open\n").is_err());
    }
}
