//! Configuration types for all schedulers + a minimal config-file loader.
//!
//! File format (offline build — no TOML crate): `key = value` lines with
//! `#` comments and `[section]` headers flattened to `section.key`.

pub mod file;

use crate::cluster::{ClusterSpec, NodeCatalog};
use crate::sim::fault::FaultPlan;
use crate::sim::net::NetModel;
use crate::sim::time::SimTime;

/// Parameters shared by every simulated architecture.
#[derive(Clone, Debug)]
pub struct SimParams {
    /// One-way network latency model (paper: constant 0.5 ms).
    pub net: NetModel,
    /// Threshold on estimated (mean) task duration separating short from
    /// long jobs, for the priority-aware baselines and for Figs. 3c/3d.
    pub short_threshold: SimTime,
    /// RNG seed; every run is a pure function of (config, trace, seed).
    pub seed: u64,
    /// Route bitmap queries through the occupancy index (summary bitmap
    /// + block popcounts + per-node counters; `cluster::bitmap`). The
    /// index is bit-identity-gated, so `false` only selects the flat
    /// `naive_*` scans — the `--no-index` debug mode and the
    /// differential goldens in `tests/index_oracle.rs`.
    pub use_index: bool,
    /// Execution shards for one run (`--shards N`). 1 = the classic
    /// sequential driver. N > 1 partitions the cluster state into N
    /// shards and drains events in network-lookahead epochs, either on N
    /// threads or serially — the two are bit-identical by construction
    /// (`tests/shard_identity.rs`). Megha, Sparrow, and Eagle shard;
    /// Pigeon falls back to 1 with [`crate::metrics::ShardFallback`]
    /// recorded on the outcome.
    pub shards: usize,
    /// Idle-epoch fast-forward for sharded runs (default `true`): at
    /// each barrier the next epoch starts at the *global minimum*
    /// next-event time instead of tiling the clock in contiguous
    /// `window`-wide steps, so sparse stretches cost one epoch instead
    /// of thousands. Computed identically in threaded and sequential
    /// modes; on constant-delay networks the on/off schedules are
    /// bit-identical too (`tests/shard_identity.rs` pins this). `false`
    /// is the dense-grid debug/reference mode.
    pub fast_forward: bool,
    /// Flight recorder (`obs::flight`, CLI `--flight-record`): record
    /// every scheduler decision into a per-run event log with staleness
    /// accounting. Off by default; recording is *inert* — the simulated
    /// schedule is bit-identical on or off (`tests/driver_invariants.rs`)
    /// and only [`RunOutcome::flight`](crate::metrics::RunOutcome) /
    /// [`flight_log`](crate::metrics::RunOutcome::flight_log) change.
    pub flight: bool,
    /// Compiled fault schedule (`sim::fault`, CLI `--churn` /
    /// `--rack-outages`): node churn, correlated rack outages, and GM
    /// failures, injected by each scheduler at init into the lane that
    /// owns the faulted state. `None` (the default) and the empty plan
    /// are both inert — the run is bit-identical to a fault-free one
    /// (`tests/driver_invariants.rs` pins this).
    pub fault: Option<FaultPlan>,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            net: NetModel::paper_default(),
            short_threshold: SimTime::from_secs(90.0),
            seed: 0,
            use_index: true,
            shards: 1,
            fast_forward: true,
            flight: false,
            fault: None,
        }
    }
}

/// Megha (§3): GM/LM federation with eventually-consistent global state.
#[derive(Clone, Debug)]
pub struct MeghaConfig {
    pub spec: ClusterSpec,
    pub sim: SimParams,
    /// LM heartbeat interval (paper: 5 s simulation, 10 s prototype).
    pub heartbeat: SimTime,
    /// Max task→node mappings per GM→LM batch (§3.4.1 caps batch size).
    pub max_batch: usize,
    /// Per-GM worker shuffle to reduce collisions (§3.3). When false the
    /// ablation bench measures the extra inconsistencies.
    pub shuffle_workers: bool,
    /// Use the XLA (PJRT) match engine instead of the Rust fallback.
    pub use_xla_match: bool,
    /// Per-worker capacity/attribute catalog (`cluster::hetero`). The
    /// default is the trivial uniform catalog, which is guaranteed
    /// bit-identical to the pre-hetero behavior.
    pub catalog: NodeCatalog,
}

impl MeghaConfig {
    /// Paper-shaped defaults for a DC of `workers` nodes.
    pub fn for_workers(workers: usize) -> MeghaConfig {
        // paper's prototype uses 3 GMs; simulations use more at scale
        let n_gm = if workers <= 1000 { 3 } else { 8 };
        let n_lm = if workers <= 1000 { 3 } else { 10 };
        let spec = ClusterSpec::for_workers(workers, n_gm, n_lm);
        MeghaConfig {
            spec,
            sim: SimParams::default(),
            heartbeat: SimTime::from_secs(5.0),
            max_batch: 64,
            shuffle_workers: true,
            use_xla_match: false,
            catalog: NodeCatalog::uniform(spec.n_workers()),
        }
    }
}

/// Sparrow (§2.2.2): batch sampling + late binding.
#[derive(Clone, Debug)]
pub struct SparrowConfig {
    pub workers: usize,
    pub n_schedulers: usize,
    /// Probe ratio d: d·n probes per n-task job (paper/Sparrow: d = 2).
    pub probe_ratio: usize,
    pub sim: SimParams,
    /// See [`MeghaConfig::catalog`]. Probes stay blind to it; it is
    /// consulted only to *verify* constraints at probed nodes.
    pub catalog: NodeCatalog,
}

impl SparrowConfig {
    pub fn for_workers(workers: usize) -> SparrowConfig {
        SparrowConfig {
            workers,
            n_schedulers: 8,
            probe_ratio: 2,
            sim: SimParams::default(),
            catalog: NodeCatalog::uniform(workers),
        }
    }
}

/// Eagle (§2.2.3): hybrid centralized (long) + distributed (short) with
/// succinct state sharing and sticky batch probing.
#[derive(Clone, Debug)]
pub struct EagleConfig {
    pub workers: usize,
    pub n_schedulers: usize,
    pub probe_ratio: usize,
    /// Fraction of the DC reserved for short jobs only (long jobs are
    /// confined to the complement).
    pub short_partition_frac: f64,
    pub sim: SimParams,
    /// See [`SparrowConfig::catalog`]: short-job probes verify at the
    /// node; only the *centralized* long-job scheduler places
    /// constraint-aware.
    pub catalog: NodeCatalog,
}

impl EagleConfig {
    pub fn for_workers(workers: usize) -> EagleConfig {
        EagleConfig {
            workers,
            n_schedulers: 8,
            probe_ratio: 2,
            short_partition_frac: 0.09, // Eagle paper's default split
            sim: SimParams::default(),
            catalog: NodeCatalog::uniform(workers),
        }
    }
}

/// Pigeon (§2.2.4): distributors + per-group coordinators with weighted
/// fair queues and workers reserved for high-priority tasks.
#[derive(Clone, Debug)]
pub struct PigeonConfig {
    pub workers: usize,
    pub n_distributors: usize,
    pub n_groups: usize,
    /// Workers per group reserved for high-priority (short) tasks.
    pub reserved_frac: f64,
    /// Weighted fair queuing: 1 low-priority task per `wfq_weight` high.
    pub wfq_weight: usize,
    pub sim: SimParams,
    /// See [`SparrowConfig::catalog`]: distributors route constrained
    /// tasks only to groups with matching nodes (static knowledge);
    /// coordinators verify against live state.
    pub catalog: NodeCatalog,
}

impl PigeonConfig {
    pub fn for_workers(workers: usize) -> PigeonConfig {
        PigeonConfig {
            workers,
            n_distributors: 8,
            n_groups: (workers / 100).clamp(3, 130),
            reserved_frac: 0.04, // Pigeon paper: ~3.5-4% reserved
            wfq_weight: 10,
            sim: SimParams::default(),
            catalog: NodeCatalog::uniform(workers),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn megha_defaults_cover_workers() {
        for &w in &[480usize, 3000, 13_000, 50_000] {
            let c = MeghaConfig::for_workers(w);
            assert!(c.spec.n_workers() >= w);
        }
    }

    #[test]
    fn pigeon_group_count_bounds() {
        assert_eq!(PigeonConfig::for_workers(200).n_groups, 3);
        assert_eq!(PigeonConfig::for_workers(13_000).n_groups, 130);
        assert_eq!(PigeonConfig::for_workers(100_000).n_groups, 130);
    }

    #[test]
    fn default_net_is_half_ms() {
        let p = SimParams::default();
        match p.net {
            NetModel::Constant(d) => assert_eq!(d, SimTime::from_millis(0.5)),
            _ => panic!("default must be constant"),
        }
    }
}
