//! Benchmark suite (criterion is unavailable offline, so this is a
//! self-contained harness: warmup + timed iterations, median-of-runs).
//!
//! Two kinds of benches:
//! 1. **paper regeneration** — one bench per table/figure (table1, fig2,
//!    fig3a/b, fig4a/b, headline) at smoke scale, printing the rows and
//!    their wall-clock cost;
//! 2. **microbenches** — the hot paths: match engines (Rust vs XLA),
//!    simulator event throughput, bitmap scans, wire codec.
//!
//! Run with `cargo bench` (or `cargo bench -- fig3 match` to filter).
//! Flags: `--quick` shrinks the per-bench budget (the CI smoke mode);
//! `--json` additionally writes `BENCH_PR10.json` (per-bench median
//! ns/unit, experiment totals in seconds) at the repo root — the
//! current PR's perf artifact (`BENCH_PR2.json` … `BENCH_PR9.json` are
//! the frozen earlier snapshots, still pending hardware regeneration).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use megha::cluster::AvailMap;
use megha::config::MeghaConfig;
use megha::experiments::{fig2, fig3, fig4, headline, table1, Scale};
use megha::proto::messages::{MapReq, Msg};
use megha::runtime::match_engine::{MatchPlanner, RustMatchEngine};
use megha::runtime::pjrt::{artifacts_available, XlaMatchEngine};
use megha::sched;
use megha::sim::time::SimTime;
use megha::sim::{EventQueue, HeapEventQueue};
use megha::util::json::Json;
use megha::util::rng::Rng;
use megha::workload::synthetic::{synthetic_fixed, yahoo_like};

struct Bench {
    filter: Vec<String>,
    budget: Duration,
    max_samples: usize,
    /// (name, median ns/unit) for `time` benches, collected for --json.
    unit_results: RefCell<Vec<(String, f64)>>,
    /// (name, total seconds) for `once` benches.
    total_results: RefCell<Vec<(String, f64)>>,
}

impl Bench {
    fn enabled(&self, name: &str) -> bool {
        self.filter.is_empty() || self.filter.iter().any(|f| name.contains(f.as_str()))
    }

    /// Opt-in benches run only when the filter names them explicitly.
    fn explicitly_enabled(&self, name: &str) -> bool {
        self.filter.iter().any(|f| name.contains(f.as_str()))
    }

    /// Time `f` (called with an iteration counter), reporting per-op cost.
    fn time<F: FnMut() -> u64>(&self, name: &str, mut f: F) {
        if !self.enabled(name) {
            return;
        }
        // warmup
        let mut units = f();
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples.len() < self.max_samples {
            let t0 = Instant::now();
            units = f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = samples[samples.len() / 2];
        let per_unit = med / units.max(1) as f64;
        println!(
            "bench {name:<42} {:>10.3} ms/iter  {:>12.1} ns/unit  ({} units, {} samples)",
            med * 1e3,
            per_unit * 1e9,
            units,
            samples.len()
        );
        self.unit_results
            .borrow_mut()
            .push((name.to_string(), per_unit * 1e9));
    }

    /// Time a whole-experiment regeneration once.
    fn once<F: FnOnce()>(&self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        let t0 = Instant::now();
        f();
        let total = t0.elapsed().as_secs_f64();
        println!("bench {name:<42} {total:>10.3} s total");
        self.total_results.borrow_mut().push((name.to_string(), total));
    }

    /// Write `BENCH_PR10.json` at the repo root (next to `rust/`),
    /// merging over any existing file so successive filtered runs
    /// (`-- queue --json` then `-- scale10 --json`) accumulate instead
    /// of clobbering each other. A fresh run of a bench name replaces
    /// its previous entry.
    ///
    /// The document carries a `"measured"` flag: `true` once any run
    /// has actually contributed samples (and sticky from then on),
    /// `false` when the file holds no measurements — so stubs committed
    /// from toolchain-less containers can never be mistaken for
    /// measured numbers by readers or report tooling.
    fn write_json(&self) {
        let root = std::env::var("CARGO_MANIFEST_DIR")
            .map(std::path::PathBuf::from)
            .ok()
            .and_then(|p| p.parent().map(|q| q.to_path_buf()))
            .unwrap_or_else(|| std::path::PathBuf::from("."));
        let path = root.join("BENCH_PR10.json");
        let mut bench: BTreeMap<String, Json> = BTreeMap::new();
        let mut totals: BTreeMap<String, Json> = BTreeMap::new();
        let mut measured = false;
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(old) = Json::parse(&text) {
                if let Some(Json::Obj(m)) = old.get("bench") {
                    bench = m.clone();
                }
                if let Some(Json::Obj(m)) = old.get("experiments_total_s") {
                    totals = m.clone();
                }
                if let Some(Json::Bool(b)) = old.get("measured") {
                    measured = *b;
                }
            }
        }
        measured |= !self.unit_results.borrow().is_empty()
            || !self.total_results.borrow().is_empty();
        for (n, v) in self.unit_results.borrow().iter() {
            bench.insert(n.clone(), Json::num(*v));
        }
        for (n, v) in self.total_results.borrow().iter() {
            totals.insert(n.clone(), Json::num(*v));
        }
        let doc = Json::obj(vec![
            ("unit", Json::str("ns_per_unit")),
            ("measured", Json::Bool(measured)),
            ("bench", Json::Obj(bench)),
            ("experiments_total_s", Json::Obj(totals)),
        ]);
        match std::fs::write(&path, doc.encode()) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("failed to write {}: {e}", path.display()),
        }
    }
}

fn main() {
    let flags: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a.starts_with("--"))
        .collect();
    let quick = flags.iter().any(|a| a == "--quick");
    let json = flags.iter().any(|a| a == "--json");
    let filter: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--"))
        .collect();
    let b = Bench {
        filter,
        budget: if quick {
            Duration::from_millis(250)
        } else {
            Duration::from_secs(2)
        },
        max_samples: if quick { 5 } else { 15 },
        unit_results: RefCell::new(Vec::new()),
        total_results: RefCell::new(Vec::new()),
    };
    println!("== megha bench suite ==");

    // ---- 1. paper regeneration (smoke scale) ----
    b.once("paper/table1", || {
        table1::run(Scale::Smoke, 0);
    });
    b.once("paper/fig2_scalability", || {
        fig2::run(Scale::Smoke, 0);
    });
    b.once("paper/fig3a_yahoo_frameworks", || {
        fig3::run(fig3::Workload::Yahoo, Scale::Smoke, 0);
    });
    b.once("paper/fig3b_google_frameworks", || {
        fig3::run(fig3::Workload::Google, Scale::Smoke, 0);
    });
    b.once("paper/fig4a_prototype_yahoo", || {
        let _ = fig4::run(fig4::Workload::Yahoo, Scale::Smoke, 0);
    });
    b.once("paper/fig4b_prototype_google", || {
        let _ = fig4::run(fig4::Workload::Google, Scale::Smoke, 0);
    });
    b.once("paper/headline_ratios", || {
        headline::run(Scale::Smoke, 0);
    });

    // ---- 2. microbenches ----
    bench_match_engines(&b);
    bench_constraint_match(&b);
    bench_gang_queries(&b);
    bench_index(&b);
    bench_sim_throughput(&b);
    bench_bitmap(&b);
    bench_queue(&b);
    bench_snapshot(&b);
    bench_codec(&b);
    bench_ablation_batching(&b);
    bench_ablation_shuffle(&b);
    bench_sweep_speedup(&b);
    bench_flight(&b);
    bench_fault(&b);
    bench_scale10(&b);
    bench_shard(&b);
    bench_scale100(&b);
    if json {
        b.write_json();
    }
    println!("== done ==");
}

/// Event-queue family: the bucketed calendar queue vs the retained
/// `BinaryHeap` oracle, on (a) bulk fill-then-drain and (b) the classic
/// DES *hold* pattern (pop one, push one at a random future offset) —
/// the access pattern of a running simulation.
fn bench_queue(b: &Bench) {
    const N: usize = 100_000;
    const HOLD_OPS: usize = 200_000;
    b.time("queue/bucketed_fill_drain_100k", || {
        let mut rng = Rng::new(1);
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..N {
            q.push(SimTime::from_micros(rng.below(10_000_000) as u64), i as u32);
        }
        while q.pop().is_some() {}
        std::hint::black_box(q.popped());
        2 * N as u64
    });
    b.time("queue/heap_oracle_fill_drain_100k", || {
        let mut rng = Rng::new(1);
        let mut q: HeapEventQueue<u32> = HeapEventQueue::new();
        for i in 0..N {
            q.push(SimTime::from_micros(rng.below(10_000_000) as u64), i as u32);
        }
        while q.pop().is_some() {}
        std::hint::black_box(q.popped());
        2 * N as u64
    });
    b.time("queue/bucketed_hold_50k", || {
        let mut rng = Rng::new(2);
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..50_000 {
            q.push(SimTime::from_micros(rng.below(5_000_000) as u64), i);
        }
        for _ in 0..HOLD_OPS {
            let (_, e) = q.pop().expect("queue kept at steady size");
            q.push_after(SimTime::from_micros(rng.below(5_000_000) as u64 + 1), e);
        }
        while q.pop().is_some() {}
        std::hint::black_box(q.popped());
        2 * HOLD_OPS as u64
    });
    b.time("queue/heap_oracle_hold_50k", || {
        let mut rng = Rng::new(2);
        let mut q: HeapEventQueue<u32> = HeapEventQueue::new();
        for i in 0..50_000 {
            q.push(SimTime::from_micros(rng.below(5_000_000) as u64), i);
        }
        for _ in 0..HOLD_OPS {
            let (_, e) = q.pop().expect("queue kept at steady size");
            q.push_after(SimTime::from_micros(rng.below(5_000_000) as u64 + 1), e);
        }
        while q.pop().is_some() {}
        std::hint::black_box(q.popped());
        2 * HOLD_OPS as u64
    });
}

/// Snapshot family: the old shape (full-width clone + ranged overwrite)
/// vs the delta shape (range-word export + `apply_words`), plus the
/// masked fast path, at a 100k-worker DC with 10k-worker LM ranges.
fn bench_snapshot(b: &Bench) {
    const N: usize = 100_000;
    const LO: usize = 40_000;
    const HI: usize = 50_000;
    let mut rng = Rng::new(3);
    let mut lm = AvailMap::all_free(N);
    for _ in 0..N / 2 {
        lm.set_busy(rng.below(N));
    }
    let mut gm = AvailMap::all_free(N);
    for _ in 0..N / 2 {
        gm.set_busy(rng.below(N));
    }
    b.time("snapshot/full_clone_apply_100k", || {
        let mut acc = 0usize;
        for _ in 0..200 {
            let snap = lm.clone(); // the old wire shape: whole DC
            let mut view = gm.clone();
            view.copy_range_from(&snap, LO, HI);
            acc += view.free_count();
        }
        std::hint::black_box(acc);
        200
    });
    let mut words = Vec::new();
    b.time("snapshot/delta_export_apply_100k", || {
        let mut acc = 0usize;
        let mut changed = Vec::new();
        for _ in 0..200 {
            lm.copy_words_into(LO, HI, &mut words); // delta wire shape
            let mut view = gm.clone();
            view.apply_words(LO, HI, &words, None, &mut changed);
            acc += view.free_count();
        }
        std::hint::black_box(acc);
        200
    });
    lm.copy_words_into(LO, HI, &mut words);
    // sparse dirty mask: ~1 word in 16 marked changed
    let mut mask = vec![0u64; words.len().div_ceil(64)];
    for i in (0..words.len()).step_by(16) {
        mask[i / 64] |= 1 << (i % 64);
    }
    b.time("snapshot/delta_masked_apply_100k", || {
        let mut acc = 0usize;
        let mut changed = Vec::new();
        for _ in 0..200 {
            let mut view = gm.clone();
            view.apply_words(LO, HI, &words, Some(&mask), &mut changed);
            acc += view.free_count();
        }
        std::hint::black_box(acc);
        200
    });
}

/// The ISSUE-8 flight-recorder family: whole-sim cost with the recorder
/// off vs on (`flight/megha_yahoo300_off` must match the retained
/// `sim/megha_yahoo300_tasks` baseline — off is one predictable branch
/// per instrumented site; on must stay within ~10%), the raw `record`
/// throughput of the chunked buffer, and the columnar export/read
/// round-trip on a synthetic log.
fn bench_flight(b: &Bench) {
    use megha::obs::flight::{
        read_columnar, write_columnar, Actor, EvKind, FlightEvent, FlightRecorder, NONE,
    };
    let mut cfg = MeghaConfig::for_workers(3_000);
    cfg.sim.seed = 7;
    let trace = yahoo_like(300, 3_000, 0.85, 7);
    let n_tasks = trace.n_tasks() as u64;
    b.time("flight/megha_yahoo300_off", || {
        let out = sched::megha::simulate(&cfg, &trace);
        std::hint::black_box(out.decisions);
        n_tasks
    });
    let mut on = cfg.clone();
    on.sim.flight = true;
    b.time("flight/megha_yahoo300_on", || {
        let out = sched::megha::simulate(&on, &trace);
        std::hint::black_box(out.flight.map(|f| f.events));
        n_tasks
    });
    b.time("flight/record_1m", || {
        let mut rec = FlightRecorder::new(true);
        for i in 0..1_000_000u64 {
            rec.record(
                SimTime::from_micros(i),
                EvKind::GmMatch,
                Actor::Gm((i % 8) as u32),
                i as u32,
                0,
                i,
            );
        }
        std::hint::black_box(rec.len());
        1_000_000
    });
    let log: Vec<FlightEvent> = (0..200_000u64)
        .map(|i| FlightEvent {
            t_us: i,
            kind: EvKind::ALL[(i % 18) as usize],
            actor: Actor::Sched((i % 8) as u32).encode(),
            job: i as u32,
            task: NONE,
            payload: i,
        })
        .collect();
    let dir = std::env::temp_dir().join(format!("megha-flight-bench-{}", std::process::id()));
    b.time("flight/columnar_roundtrip_200k", || {
        write_columnar(&dir, &log).expect("columnar write");
        let back = read_columnar(&dir).expect("columnar read");
        std::hint::black_box(back.len());
        200_000
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// The ISSUE-10 fault-injection family: `FaultPlan` compilation
/// throughput at DC scale, and whole-sim cost with a busy churn plan vs
/// the retained fault-free baselines (`fault/megha_churn_yahoo300`
/// against `sim/megha_yahoo300_tasks`, likewise Sparrow) — the kill /
/// park / re-dispatch machinery plus the recovery-SLO accounting is the
/// delta being measured.
fn bench_fault(b: &Bench) {
    use megha::sim::fault::{FaultPlan, FaultSpec};
    let churny = FaultSpec {
        churn_per_khour: 400.0,
        downtime_s: 15.0,
        drain_frac: 0.25,
        rack_outages: 2,
        horizon_s: 120.0,
        degrade: None,
    };
    let big = megha::cluster::NodeCatalog::rack_tiered(20_000, 0.25);
    b.time("fault/plan_compile_20k", || {
        let mut events = 0u64;
        for seed in 0..50u64 {
            let plan = FaultPlan::compile(&churny, &big, seed);
            events += plan.events().len() as u64;
        }
        std::hint::black_box(events);
        50
    });
    let mut cfg = MeghaConfig::for_workers(3_000);
    cfg.sim.seed = 7;
    cfg.sim.fault = Some(FaultPlan::compile(&churny, &cfg.catalog, 7));
    let trace = yahoo_like(300, 3_000, 0.85, 7);
    let n_tasks = trace.n_tasks() as u64;
    b.time("fault/megha_churn_yahoo300", || {
        let out = sched::megha::simulate(&cfg, &trace);
        std::hint::black_box((out.tasks_killed, out.redispatch_s.len()));
        n_tasks
    });
    let mut scfg = megha::config::SparrowConfig::for_workers(3_000);
    scfg.sim.seed = 7;
    scfg.sim.fault = Some(FaultPlan::compile(&churny, &scfg.catalog, 7));
    b.time("fault/sparrow_churn_yahoo300", || {
        let out = sched::sparrow::simulate(&scfg, &trace);
        std::hint::black_box((out.tasks_killed, out.redispatch_s.len()));
        n_tasks
    });
}

/// The ISSUE-2 acceptance scenario: fig3a Yahoo at 10× jobs and 10×
/// workers through the sweep harness. Heavyweight, so opt-in: run with
/// `cargo bench -- scale10`.
fn bench_scale10(b: &Bench) {
    if !b.explicitly_enabled("scale10") {
        return;
    }
    let spec = megha::sweep::SweepSpec {
        frameworks: vec!["megha".into(), "sparrow".into()],
        scenarios: megha::sweep::preset("scale10", &megha::sim::net::NetModel::paper_default())
            .expect("scale10 preset"),
        seeds: 1,
        base_seed: 0,
        threads: 0,
    };
    let t0 = Instant::now();
    let res = megha::sweep::run_sweep(&spec);
    let total = t0.elapsed().as_secs_f64();
    for r in &res.records {
        println!(
            "bench sweep/scale10/{:<28} {:>10.3} s  {:>12.0} events/s  ({} events)",
            r.framework,
            r.wall_s,
            r.events_per_sec(),
            r.events
        );
        b.total_results
            .borrow_mut()
            .push((format!("sweep/scale10/{}", r.framework), r.wall_s));
    }
    println!("bench sweep/scale10_total                        {total:>10.3} s total");
}

/// The ISSUE-6/7/9 sharded-execution family: Megha, Sparrow, and Eagle
/// runs at shard counts 1/2/4/8 (same trace; each shard count is its own
/// deterministic schedule), reporting events/s scaling of the threaded
/// driver, the sequential reference of the widest schedule so the
/// epoch/barrier machinery's single-thread overhead is visible, and a
/// fast-forward on/off pair quantifying what the idle-epoch skip is
/// worth. Heavyweight, so opt-in: `cargo bench -- shard`.
fn bench_shard(b: &Bench) {
    if !b.explicitly_enabled("shard") {
        return;
    }
    let trace = yahoo_like(2_000, 20_000, 0.85, 11);
    for &shards in &[1usize, 2, 4, 8] {
        let mut cfg = MeghaConfig::for_workers(20_000);
        cfg.sim.seed = 11;
        cfg.sim.shards = shards;
        let t0 = Instant::now();
        let out = sched::megha::simulate(&cfg, &trace);
        let total = t0.elapsed().as_secs_f64();
        println!(
            "bench shard/megha_yahoo2k_s{shards:<2}                     {:>10.3} s  {:>12.0} events/s  ({} events, {} shards)",
            total,
            out.events_per_sec(),
            out.events,
            out.shards
        );
        b.total_results
            .borrow_mut()
            .push((format!("shard/megha_yahoo2k_s{shards}"), total));
    }
    {
        let mut cfg = MeghaConfig::for_workers(20_000);
        cfg.sim.seed = 11;
        cfg.sim.shards = 8;
        let t0 = Instant::now();
        let out = sched::megha::simulate_sharded_reference(&cfg, &trace, None);
        let total = t0.elapsed().as_secs_f64();
        println!(
            "bench shard/megha_yahoo2k_s8_reference           {:>10.3} s  {:>12.0} events/s  (sequential lanes)",
            total,
            out.events_per_sec()
        );
        b.total_results
            .borrow_mut()
            .push(("shard/megha_yahoo2k_s8_reference".into(), total));
    }
    // Sparrow on the same trace: probe fan-out is the cross-shard
    // traffic, so this is the stress case for the exchange matrix
    for &shards in &[1usize, 2, 4, 8] {
        let mut cfg = megha::config::SparrowConfig::for_workers(20_000);
        cfg.sim.seed = 11;
        cfg.sim.shards = shards;
        let t0 = Instant::now();
        let out = if shards > 1 {
            sched::sparrow_sharded::simulate_sharded(&cfg, &trace)
        } else {
            sched::sparrow::simulate(&cfg, &trace)
        };
        let total = t0.elapsed().as_secs_f64();
        println!(
            "bench shard/sparrow_yahoo2k_s{shards:<2}                   {:>10.3} s  {:>12.0} events/s  ({} events, {} shards)",
            total,
            out.events_per_sec(),
            out.events,
            out.shards
        );
        b.total_results
            .borrow_mut()
            .push((format!("shard/sparrow_yahoo2k_s{shards}"), total));
    }
    {
        let mut cfg = megha::config::SparrowConfig::for_workers(20_000);
        cfg.sim.seed = 11;
        cfg.sim.shards = 8;
        let t0 = Instant::now();
        let out = sched::sparrow_sharded::simulate_sharded_reference(&cfg, &trace);
        let total = t0.elapsed().as_secs_f64();
        println!(
            "bench shard/sparrow_yahoo2k_s8_reference         {:>10.3} s  {:>12.0} events/s  (sequential lanes)",
            total,
            out.events_per_sec()
        );
        b.total_results
            .borrow_mut()
            .push(("shard/sparrow_yahoo2k_s8_reference".into(), total));
    }
    // Eagle on the same trace: the hybrid split — short-job probe
    // fan-out plus the pinned central long scheduler, whose
    // LongPlace/Done round trips all cross shards from shard 0
    for &shards in &[1usize, 2, 4, 8] {
        let mut cfg = megha::config::EagleConfig::for_workers(20_000);
        cfg.sim.seed = 11;
        cfg.sim.shards = shards;
        let t0 = Instant::now();
        let out = if shards > 1 {
            sched::eagle_sharded::simulate_sharded(&cfg, &trace)
        } else {
            sched::eagle::simulate(&cfg, &trace)
        };
        let total = t0.elapsed().as_secs_f64();
        println!(
            "bench shard/eagle_yahoo2k_s{shards:<2}                     {:>10.3} s  {:>12.0} events/s  ({} events, {} shards)",
            total,
            out.events_per_sec(),
            out.events,
            out.shards
        );
        b.total_results
            .borrow_mut()
            .push((format!("shard/eagle_yahoo2k_s{shards}"), total));
    }
    {
        let mut cfg = megha::config::EagleConfig::for_workers(20_000);
        cfg.sim.seed = 11;
        cfg.sim.shards = 8;
        let t0 = Instant::now();
        let out = sched::eagle_sharded::simulate_sharded_reference(&cfg, &trace);
        let total = t0.elapsed().as_secs_f64();
        println!(
            "bench shard/eagle_yahoo2k_s8_reference           {:>10.3} s  {:>12.0} events/s  (sequential lanes)",
            total,
            out.events_per_sec()
        );
        b.total_results
            .borrow_mut()
            .push(("shard/eagle_yahoo2k_s8_reference".into(), total));
    }
    // fast-forward on/off: a sparse trace where idle-epoch skipping is
    // the dominant cost difference (bit-identical outcomes, see
    // tests/shard_identity.rs)
    let sparse = yahoo_like(400, 20_000, 0.25, 13);
    for ff in [true, false] {
        let mut cfg = megha::config::SparrowConfig::for_workers(20_000);
        cfg.sim.seed = 13;
        cfg.sim.shards = 8;
        cfg.sim.fast_forward = ff;
        let t0 = Instant::now();
        let out = sched::sparrow_sharded::simulate_sharded(&cfg, &sparse);
        let total = t0.elapsed().as_secs_f64();
        let tag = if ff { "ff_on " } else { "ff_off" };
        println!(
            "bench shard/sparrow_sparse_s8_{tag}             {:>10.3} s  {:>12.0} events/s  ({} events)",
            total,
            out.events_per_sec(),
            out.events
        );
        b.total_results
            .borrow_mut()
            .push((format!("shard/sparrow_sparse_s8_{}", tag.trim()), total));
    }
}

/// The ISSUE-6 acceptance scenario: the `scale100` preset (~1M worker
/// slots, 8 shards) through the sweep harness. Very heavy, so opt-in:
/// `cargo bench -- scale100` (add `--quick` to get the `--smoke`-sized
/// rendition the CI step runs).
fn bench_scale100(b: &Bench) {
    if !b.explicitly_enabled("scale100") {
        return;
    }
    let quick = b.budget < Duration::from_secs(1);
    let scenarios: Vec<megha::sweep::Scenario> =
        megha::sweep::preset("scale100", &megha::sim::net::NetModel::paper_default())
            .expect("scale100 preset")
            .into_iter()
            .map(|sc| if quick { sc.smoke() } else { sc })
            .collect();
    let spec = megha::sweep::SweepSpec {
        frameworks: vec!["megha".into()],
        scenarios,
        seeds: 1,
        base_seed: 0,
        threads: 0,
    };
    let t0 = Instant::now();
    let res = megha::sweep::run_sweep(&spec);
    let total = t0.elapsed().as_secs_f64();
    for r in &res.records {
        println!(
            "bench sweep/scale100/{:<27} {:>10.3} s  {:>12.0} events/s  ({} events, {} shards)",
            r.framework,
            r.wall_s,
            r.events_per_sec(),
            r.events,
            r.shards
        );
        b.total_results
            .borrow_mut()
            .push((format!("sweep/scale100/{}", r.framework), r.wall_s));
    }
    println!("bench sweep/scale100_total                       {total:>10.3} s total");
}

/// Parallel sweep harness: the same 4×2×4 grid executed with one thread
/// and with all cores, comparing true sequential vs parallel wall-clock
/// (results are bit-identical across thread counts).
fn bench_sweep_speedup(b: &Bench) {
    if !b.enabled("sweep/parallel") {
        return;
    }
    let mk_spec = |threads: usize| megha::sweep::SweepSpec {
        frameworks: megha::sweep::FRAMEWORKS.iter().map(|s| s.to_string()).collect(),
        scenarios: megha::sweep::scenario_grid(
            &megha::sweep::WorkloadKind::Fixed { tasks_per_job: 50 },
            &[400],
            &[0.6, 0.9],
            40,
            &megha::sim::net::NetModel::paper_default(),
            None,
            None,
        ),
        seeds: 4,
        base_seed: 1,
        threads,
    };
    let seq = megha::sweep::run_sweep(&mk_spec(1));
    let par = megha::sweep::run_sweep(&mk_spec(0));
    println!(
        "bench sweep/parallel_4x2x4                       {:>10.3} s sequential  {:>10.3} s parallel  true speedup {:.2}x on {} threads",
        seq.wall_s,
        par.wall_s,
        if par.wall_s > 0.0 { seq.wall_s / par.wall_s } else { 0.0 },
        par.threads
    );
}

/// L1/L2/L3 hot path: the match operation, Rust vs XLA (PJRT).
fn bench_match_engines(b: &Bench) {
    let mut rng = Rng::new(1);
    let p = 80usize; // the fig3 topology size
    let free: Vec<u32> = (0..p).map(|_| rng.below(65) as u32).collect();
    let internal: Vec<bool> = (0..p).map(|i| i % 8 == 0).collect();
    b.time("match/rust_plan_80p", || {
        let mut total = 0u64;
        for rr in 0..1000 {
            let plan = RustMatchEngine.plan(&free, &internal, rr % p, 256);
            total += plan.len() as u64;
        }
        std::hint::black_box(total);
        1000
    });
    let free_big: Vec<u32> = (0..1024).map(|_| rng.below(65) as u32).collect();
    let internal_big: Vec<bool> = (0..1024).map(|i| i % 8 == 0).collect();
    b.time("match/rust_plan_1024p", || {
        let mut total = 0u64;
        for rr in 0..1000 {
            let plan = RustMatchEngine.plan(&free_big, &internal_big, rr % 1024, 512);
            total += plan.len() as u64;
        }
        std::hint::black_box(total);
        1000
    });
    if artifacts_available() {
        let mut eng = XlaMatchEngine::load_default().expect("artifacts");
        b.time("match/xla_plan_1024p", || {
            let mut total = 0u64;
            for rr in 0..20 {
                let plan = eng.plan(&free_big, &internal_big, rr % 1024, 512);
                total += plan.len() as u64;
            }
            std::hint::black_box(total);
            20
        });
    } else {
        println!("bench match/xla_plan_1024p                       SKIPPED (run `make artifacts`)");
    }
}

/// Constraint matching at fig3 scale: the catalog's word-wise masked
/// scans (AND of state word × attribute/capacity masks) vs a naive
/// per-worker filter (`is_free && slot_matches`). The masked path is
/// what Megha's `constrained_plan` runs per scheduling round.
fn bench_constraint_match(b: &Bench) {
    use megha::cluster::NodeCatalog;
    use megha::workload::Demand;
    const N: usize = 6_400; // fig3-scale DC
    let catalog = NodeCatalog::bimodal_gpu(N, 0.0625);
    let rd = catalog
        .resolve(&Demand::attrs(&["gpu"]))
        .expect("gpu resolves");
    let mut state = AvailMap::all_free(N);
    let mut rng = Rng::new(17);
    for _ in 0..N / 2 {
        state.set_busy(rng.below(N));
    }
    const RANGE: usize = 800; // one LM-cluster-sized scan window
    b.time("match/masked_count_6400w", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let lo = (i * 613) % (N - RANGE);
            acc += catalog.count_matching_free(&state, lo, lo + RANGE, &rd);
        }
        std::hint::black_box(acc);
        1000
    });
    b.time("match/naive_count_6400w", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let lo = (i * 613) % (N - RANGE);
            acc += (lo..lo + RANGE)
                .filter(|&s| state.is_free(s) && catalog.slot_matches(s, &rd))
                .count();
        }
        std::hint::black_box(acc);
        1000
    });
    b.time("match/masked_first_free_6400w", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let lo = (i * 613) % (N - RANGE);
            acc += catalog
                .first_matching_free(&state, lo, lo + RANGE, &rd)
                .unwrap_or(0);
        }
        std::hint::black_box(acc);
        1000
    });
    b.time("match/naive_first_free_6400w", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let lo = (i * 613) % (N - RANGE);
            acc += (lo..lo + RANGE)
                .find(|&s| state.is_free(s) && catalog.slot_matches(s, &rd))
                .unwrap_or(0);
        }
        std::hint::black_box(acc);
        1000
    });
}

/// Gang placement at fig3 scale: the word-wise node scan
/// (`find_node_with_free` / `count_gangs_free`) vs a naive per-node
/// filter over the same occupancy. This is what `gang_plan` and the
/// claim path run per scheduling round for multi-slot demands.
fn bench_gang_queries(b: &Bench) {
    use megha::cluster::NodeCatalog;
    use megha::workload::Demand;
    const N: usize = 6_400;
    let catalog = NodeCatalog::bimodal_gpu(N, 0.25);
    let rd = catalog
        .resolve(&Demand::new(2, vec!["gpu".into()]))
        .expect("gpu pairs resolve");
    let mut state = AvailMap::all_free(N);
    let mut rng = Rng::new(23);
    for _ in 0..N / 2 {
        state.set_busy(rng.below(N));
    }
    const RANGE: usize = 800;
    b.time("gang/find_node_6400w", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let lo = (i * 613) % (N - RANGE);
            acc += catalog
                .find_node_with_free(&state, lo, lo + RANGE, &rd, 2)
                .unwrap_or(0) as usize;
        }
        std::hint::black_box(acc);
        1000
    });
    b.time("gang/naive_find_node_6400w", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let lo = (i * 613) % (N - RANGE);
            let hi = lo + RANGE;
            let found = (0..catalog.n_nodes() as u32).find(|&n| {
                let (nlo, nhi) = catalog.node_range(n);
                nlo >= lo
                    && nhi <= hi
                    && catalog.slot_matches(nlo, &rd)
                    && (nlo..nhi).filter(|&s| state.is_free(s)).count() >= 2
            });
            acc += found.unwrap_or(0) as usize;
        }
        std::hint::black_box(acc);
        1000
    });
    b.time("gang/count_gangs_6400w", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let lo = (i * 613) % (N - RANGE);
            acc += catalog.count_gangs_free(&state, lo, lo + RANGE, &rd);
        }
        std::hint::black_box(acc);
        1000
    });
}

/// The occupancy-index family (ISSUE 5): summary/block/counter-guided
/// queries (`index/*`) vs the retained flat scans (`index/flat_*`) on a
/// 100k-slot DC at 50/90/99% utilization — exactly where the flat scans
/// degrade (at 90%+ almost every word is zero and a flat `first_free`
/// walks them all). The acceptance target is ≥2× for `first_free` and
/// `gangs_free` at 90%+ utilization; both sides compute bit-identical
/// results (the flat side is the same map with `set_use_index(false)`).
fn bench_index(b: &Bench) {
    use megha::cluster::NodeCatalog;
    use megha::workload::Demand;
    const N: usize = 100_000;
    const RANGE: usize = 10_000; // one LM-range-sized scan window
    let catalog = NodeCatalog::bimodal_gpu(N, 0.25);
    let rd = catalog
        .resolve(&Demand::new(2, vec!["gpu".into()]))
        .expect("gpu pairs resolve");
    for &(tag, util) in &[("u50", 50usize), ("u90", 90), ("u99", 99)] {
        let mut rng = Rng::new(29 + util as u64);
        let mut state = AvailMap::all_free(N);
        catalog.attach_index(&mut state);
        let free_target = N - N * util / 100;
        while state.free_count() > free_target {
            state.set_busy(rng.below(N));
        }
        let mut flat = state.clone();
        flat.set_use_index(false);
        b.time(&format!("index/first_free_{tag}"), || {
            let mut acc = 0usize;
            for i in 0..1000 {
                let lo = (i * 613) % (N - RANGE);
                acc += state.first_free_in(lo, lo + RANGE).unwrap_or(0);
            }
            std::hint::black_box(acc);
            1000
        });
        b.time(&format!("index/flat_first_free_{tag}"), || {
            let mut acc = 0usize;
            for i in 0..1000 {
                let lo = (i * 613) % (N - RANGE);
                acc += flat.first_free_in(lo, lo + RANGE).unwrap_or(0);
            }
            std::hint::black_box(acc);
            1000
        });
        b.time(&format!("index/count_range_{tag}"), || {
            let mut acc = 0usize;
            for i in 0..1000 {
                let lo = (i * 613) % (N - RANGE);
                acc += state.count_free_in(lo, lo + RANGE);
            }
            std::hint::black_box(acc);
            1000
        });
        b.time(&format!("index/flat_count_range_{tag}"), || {
            let mut acc = 0usize;
            for i in 0..1000 {
                let lo = (i * 613) % (N - RANGE);
                acc += flat.count_free_in(lo, lo + RANGE);
            }
            std::hint::black_box(acc);
            1000
        });
        b.time(&format!("index/gangs_free_{tag}"), || {
            let mut acc = 0usize;
            for i in 0..200 {
                let lo = (i * 613) % (N - RANGE);
                acc += catalog.count_gangs_free(&state, lo, lo + RANGE, &rd);
            }
            std::hint::black_box(acc);
            200
        });
        b.time(&format!("index/flat_gangs_free_{tag}"), || {
            let mut acc = 0usize;
            for i in 0..200 {
                let lo = (i * 613) % (N - RANGE);
                acc += catalog.count_gangs_free(&flat, lo, lo + RANGE, &rd);
            }
            std::hint::black_box(acc);
            200
        });
    }
}

/// Simulator throughput: events/s and scheduling decisions/s.
fn bench_sim_throughput(b: &Bench) {
    let mut cfg = MeghaConfig::for_workers(3_000);
    cfg.sim.seed = 1;
    let trace = synthetic_fixed(200, 100, 1.0, 0.8, cfg.spec.n_workers(), 2);
    let n_tasks = trace.n_tasks() as u64;
    b.time("sim/megha_3k_workers_tasks", || {
        let out = sched::megha::simulate(&cfg, &trace);
        std::hint::black_box(out.decisions);
        n_tasks
    });
    let trace_y = yahoo_like(300, 3_000, 0.85, 3);
    let ny = trace_y.n_tasks() as u64;
    b.time("sim/megha_yahoo300_tasks", || {
        let out = sched::megha::simulate(&cfg, &trace_y);
        std::hint::black_box(out.decisions);
        ny
    });
    let mut scfg = megha::config::SparrowConfig::for_workers(3_000);
    scfg.sim.seed = 1;
    b.time("sim/sparrow_yahoo300_tasks", || {
        let out = sched::sparrow::simulate(&scfg, &trace_y);
        std::hint::black_box(out.messages);
        ny
    });
}

fn bench_bitmap(b: &Bench) {
    let mut m = AvailMap::all_free(50_000);
    let mut rng = Rng::new(5);
    for _ in 0..25_000 {
        m.set_busy(rng.below(50_000));
    }
    b.time("bitmap/count_free_50k_range", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            let lo = (i * 37) % 40_000;
            acc += m.count_free_in(lo, lo + 625);
        }
        std::hint::black_box(acc);
        1000
    });
    b.time("bitmap/pop_push_cycle", || {
        for _ in 0..10_000 {
            if let Some(w) = m.pop_free_in(0, 50_000) {
                m.set_free(w);
            }
        }
        10_000
    });
    b.time("bitmap/pop_k64_claim_release", || {
        // the ISSUE-2 one-pass pop_k_in fix: k claims in one scan
        let mut claimed = 0u64;
        for i in 0..1_000 {
            let lo = (i * 613) % 40_000;
            let ws = m.pop_k_in(lo, lo + 4_096, 64);
            claimed += ws.len() as u64;
            for w in ws {
                m.set_free(w);
            }
        }
        std::hint::black_box(claimed);
        1_000
    });
}

fn bench_codec(b: &Bench) {
    let msg = Msg::VerifyBatch {
        gm: 2,
        maps: (0..64)
            .map(|i| MapReq {
                job: i,
                task: i,
                worker: i * 3,
                dur_ms: 1500,
            })
            .collect(),
    };
    b.time("codec/verify_batch64_roundtrip", || {
        for _ in 0..1000 {
            let j = msg.to_json().encode();
            let back = Msg::from_json(&Json::parse(&j).unwrap()).unwrap();
            std::hint::black_box(&back);
        }
        1000
    });
}

/// Ablation: §3.4.1 batching — batch cap 1 vs 64 (messages + delay).
fn bench_ablation_batching(b: &Bench) {
    if !b.enabled("ablation/batching") {
        return;
    }
    let trace = synthetic_fixed(100, 60, 1.0, 0.9, 960, 4);
    let mut msgs = Vec::new();
    for &cap in &[1usize, 8, 64] {
        let mut cfg = MeghaConfig::for_workers(960);
        cfg.sim.seed = 4;
        cfg.max_batch = cap;
        let out = sched::megha::simulate(&cfg, &trace);
        msgs.push((cap, out.messages, megha::metrics::summarize_jobs(&out.jobs).p95));
    }
    println!("ablation/batching (messages, p95 delay by batch cap):");
    for (cap, m, p95) in msgs {
        println!("    max_batch={cap:<3} messages={m:<8} p95={p95:.4}s");
    }
}

/// Ablation: §3.3 per-GM shuffle on/off (inconsistency events).
fn bench_ablation_shuffle(b: &Bench) {
    if !b.enabled("ablation/shuffle") {
        return;
    }
    let trace = synthetic_fixed(100, 60, 1.0, 0.95, 960, 6);
    let mut rows = Vec::new();
    for &shuffle in &[true, false] {
        let mut cfg = MeghaConfig::for_workers(960);
        cfg.sim.seed = 6;
        cfg.shuffle_workers = shuffle;
        let out = sched::megha::simulate(&cfg, &trace);
        rows.push((shuffle, out.inconsistencies, out.inconsistency_ratio()));
    }
    println!("ablation/shuffle (inconsistencies with/without §3.3 shuffling):");
    for (s, n, r) in rows {
        println!("    shuffle={s:<5} inconsistencies={n:<6} ratio={r:.5}");
    }
}
