"""Pallas delay-stats kernel vs oracle + numpy cross-check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import delay_stats_ref
from compile.kernels.stats_kernel import delay_stats

jax.config.update("jax_platform_name", "cpu")


def _case(rng, n, n_valid, b):
    delays = rng.exponential(1.0, size=n).astype(np.float32)
    mask = np.zeros(n, dtype=np.float32)
    mask[:n_valid] = 1.0
    edges = np.sort(rng.uniform(0.0, 5.0, size=b)).astype(np.float32)
    return jnp.asarray(delays), jnp.asarray(mask), jnp.asarray(edges)


@pytest.mark.parametrize("n,b", [(512, 8), (1024, 64), (4096, 64)])
def test_stats_matches_ref(n, b):
    rng = np.random.default_rng(n + b)
    delays, mask, edges = _case(rng, n, n // 2, b)
    cdf, mom = delay_stats(delays, mask, edges)
    cdf_r, mom_r = delay_stats_ref(delays, mask, edges)
    np.testing.assert_array_equal(np.asarray(cdf), np.asarray(cdf_r))
    np.testing.assert_allclose(np.asarray(mom), np.asarray(mom_r), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    log_n=st.integers(min_value=9, max_value=12),
    b=st.sampled_from([4, 16, 64]),
    frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stats_hypothesis(log_n, b, frac, seed):
    n = 2**log_n
    rng = np.random.default_rng(seed)
    delays, mask, edges = _case(rng, n, int(frac * n), b)
    cdf, mom = delay_stats(delays, mask, edges)
    cdf_r, mom_r = delay_stats_ref(delays, mask, edges)
    np.testing.assert_array_equal(np.asarray(cdf), np.asarray(cdf_r))
    np.testing.assert_allclose(np.asarray(mom), np.asarray(mom_r), rtol=1e-5)


def test_stats_against_numpy():
    """Independent numpy check (not just oracle self-consistency)."""
    rng = np.random.default_rng(7)
    n = 2048
    delays = rng.gamma(2.0, 0.5, size=n).astype(np.float32)
    mask = (rng.random(n) < 0.8).astype(np.float32)
    edges = np.linspace(0.0, 6.0, 64, dtype=np.float32)
    cdf, mom = delay_stats(jnp.asarray(delays), jnp.asarray(mask), jnp.asarray(edges))
    valid = delays[mask > 0]
    expect_cdf = np.array([(valid <= e).sum() for e in edges], dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(cdf), expect_cdf)
    m = np.asarray(mom)
    assert m[0] == len(valid)
    np.testing.assert_allclose(m[1], valid.sum(), rtol=1e-4)
    np.testing.assert_allclose(m[3], valid.max(), rtol=1e-6)


def test_stats_all_masked():
    n, b = 512, 8
    delays = jnp.ones(n, dtype=jnp.float32)
    mask = jnp.zeros(n, dtype=jnp.float32)
    edges = jnp.linspace(0.0, 2.0, b, dtype=jnp.float32)
    cdf, mom = delay_stats(delays, mask, edges)
    assert np.all(np.asarray(cdf) == 0.0)
    m = np.asarray(mom)
    assert m[0] == 0.0 and np.isneginf(m[3])
