"""Pallas match kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes, rotation cursors and bitmap densities; every case
must match ref.py exactly (identical f32 arithmetic).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.match_kernel import match_score
from compile.kernels.ref import match_score_ref

jax.config.update("jax_platform_name", "cpu")


def _random_state(rng, n_part, n_work, density):
    avail = (rng.random((n_part, n_work)) < density).astype(np.float32)
    internal = np.zeros(n_part, dtype=np.float32)
    internal[rng.choice(n_part, size=max(1, n_part // 4), replace=False)] = 1.0
    return jnp.asarray(avail), jnp.asarray(internal)


@pytest.mark.parametrize("n_part,n_work", [(8, 8), (64, 16), (128, 64), (1024, 64)])
def test_match_matches_ref_fixed_shapes(n_part, n_work):
    rng = np.random.default_rng(n_part * 1000 + n_work)
    avail, internal = _random_state(rng, n_part, n_work, 0.5)
    rr = jnp.asarray([3 % n_part], dtype=jnp.int32)
    free, key = match_score(avail, internal, rr)
    free_r, key_r = match_score_ref(avail, internal, rr)
    np.testing.assert_array_equal(np.asarray(free), np.asarray(free_r))
    np.testing.assert_array_equal(np.asarray(key), np.asarray(key_r))


@settings(max_examples=30, deadline=None)
@given(
    log_p=st.integers(min_value=2, max_value=8),
    n_work=st.sampled_from([1, 4, 16, 64, 128]),
    rr=st.integers(min_value=0, max_value=10_000),
    density=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_match_matches_ref_hypothesis(log_p, n_work, rr, density, seed):
    n_part = 2**log_p
    rng = np.random.default_rng(seed)
    avail, internal = _random_state(rng, n_part, n_work, density)
    rr_arr = jnp.asarray([rr % n_part], dtype=jnp.int32)
    free, key = match_score(avail, internal, rr_arr)
    free_r, key_r = match_score_ref(avail, internal, rr_arr)
    np.testing.assert_array_equal(np.asarray(free), np.asarray(free_r))
    np.testing.assert_array_equal(np.asarray(key), np.asarray(key_r))


def test_key_ordering_semantics():
    """Keys encode: internal-free first, then external-free, RR within class."""
    n_part, n_work = 16, 4
    avail = jnp.ones((n_part, n_work), dtype=jnp.float32)
    avail = avail.at[5].set(0.0)  # partition 5 saturated
    internal = jnp.zeros(n_part, dtype=jnp.float32).at[2].set(1.0).at[7].set(1.0)
    rr = jnp.asarray([7], dtype=jnp.int32)
    _, key = match_score(avail, internal, rr)
    key = np.asarray(key)
    order = np.argsort(-key, kind="stable")
    # internal partitions (both free) lead, starting at rr=7
    assert list(order[:2]) == [7, 2]
    # saturated partition is last (key 0)
    assert order[-1] == 5 and key[5] == 0.0
    # external free partitions follow RR order from 7: 8,9,...,15,0,1,3,4,6
    expected_ext = [8, 9, 10, 11, 12, 13, 14, 15, 0, 1, 3, 4, 6]
    assert list(order[2 : 2 + len(expected_ext)]) == expected_ext


def test_zero_density_all_keys_zero():
    avail = jnp.zeros((32, 8), dtype=jnp.float32)
    internal = jnp.ones(32, dtype=jnp.float32)
    free, key = match_score(avail, internal, jnp.asarray([0], dtype=jnp.int32))
    assert np.all(np.asarray(free) == 0.0)
    assert np.all(np.asarray(key) == 0.0)
