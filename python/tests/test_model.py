"""L2 plan_batch: shape contract + scheduling-policy properties.

The plan is what the Rust GM executes, so the properties tested here are
the paper's placement rules: capacity is respected, internal partitions
are preferred, round-robin order holds, saturation before moving on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import plan_batch_ref

jax.config.update("jax_platform_name", "cpu")

PLAN = jax.jit(model.plan_batch)


def _state(rng, density=0.3):
    avail = (rng.random((model.P, model.W)) < density).astype(np.float32)
    internal = np.zeros(model.P, dtype=np.float32)
    internal[rng.choice(model.P, size=model.P // 4, replace=False)] = 1.0
    return jnp.asarray(avail), jnp.asarray(internal)


def test_shapes_and_dtypes():
    rng = np.random.default_rng(0)
    avail, internal = _state(rng)
    assign, free = PLAN(avail, internal, jnp.asarray([0], jnp.int32), jnp.int32(100))
    assert assign.shape == (model.T,) and assign.dtype == jnp.int32
    assert free.shape == (model.P,) and free.dtype == jnp.float32


def test_matches_ref():
    rng = np.random.default_rng(1)
    avail, internal = _state(rng)
    rr = jnp.asarray([37], jnp.int32)
    a, f = PLAN(avail, internal, rr, jnp.int32(300))
    a_r, f_r = plan_batch_ref(avail, internal, rr, 300, model.T)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(f_r))


@settings(max_examples=15, deadline=None)
@given(
    density=st.floats(min_value=0.0, max_value=0.9),
    n_tasks=st.integers(min_value=0, max_value=model.T),
    rr=st.integers(min_value=0, max_value=model.P - 1),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_plan_properties(density, n_tasks, rr, seed):
    rng = np.random.default_rng(seed)
    avail, internal = _state(rng, density)
    assign, free = PLAN(
        avail, internal, jnp.asarray([rr], jnp.int32), jnp.int32(n_tasks)
    )
    assign = np.asarray(assign)
    free = np.asarray(free)
    total_free = int(free.sum())

    # 1. number of assignments = min(n_tasks, capacity); padding is -1
    n_assigned = int((assign >= 0).sum())
    assert n_assigned == min(n_tasks, total_free)
    assert np.all(assign[n_assigned:] == -1)

    # 2. per-partition load never exceeds capacity
    used = np.bincount(assign[assign >= 0], minlength=model.P)
    assert np.all(used <= free.astype(np.int64))

    # 3. internal preference: an external partition is used only once every
    #    internal partition has been saturated
    internal_np = np.asarray(internal)
    ext_used = used[(internal_np == 0) & (used > 0)].sum()
    if ext_used > 0:
        int_idx = internal_np > 0
        assert np.array_equal(used[int_idx], free[int_idx].astype(np.int64)), (
            "external partition used while internal capacity remained"
        )


def test_internal_preference():
    """With enough internal capacity, no external partition is touched."""
    rng = np.random.default_rng(5)
    avail, internal = _state(rng, 0.5)
    internal_np = np.asarray(internal)
    free_per_part = np.asarray(avail).sum(axis=1)
    internal_cap = int(free_per_part[internal_np > 0].sum())
    n = min(internal_cap, model.T) // 2
    assign, _ = PLAN(avail, internal, jnp.asarray([0], jnp.int32), jnp.int32(n))
    assign = np.asarray(assign)
    used = assign[assign >= 0]
    assert len(used) == n
    assert np.all(internal_np[used] > 0), "external partition used despite internal capacity"


def test_saturation_before_moving_on():
    """Tasks fill one partition completely before the next (paper 3.4.1)."""
    avail = np.zeros((model.P, model.W), dtype=np.float32)
    avail[10, :5] = 1.0
    avail[20, :3] = 1.0
    internal = np.zeros(model.P, dtype=np.float32)
    internal[[10, 20]] = 1.0
    assign, _ = PLAN(
        jnp.asarray(avail), jnp.asarray(internal), jnp.asarray([0], jnp.int32), jnp.int32(8)
    )
    assign = np.asarray(assign)
    # RR from 0: partition 10 first (5 slots), then 20 (3 slots)
    assert list(assign[:8]) == [10] * 5 + [20] * 3
    assert np.all(assign[8:] == -1)


def test_round_robin_cursor_respected():
    avail = np.zeros((model.P, model.W), dtype=np.float32)
    avail[[4, 100, 600], 0] = 1.0
    internal = np.zeros(model.P, dtype=np.float32)  # all external
    assign, _ = PLAN(
        jnp.asarray(avail), jnp.asarray(internal), jnp.asarray([101], jnp.int32), jnp.int32(3)
    )
    # RR from 101: 600 first, then 4 (wraps), then 100
    assert list(np.asarray(assign[:3])) == [600, 4, 100]


def test_zero_tasks():
    rng = np.random.default_rng(2)
    avail, internal = _state(rng)
    assign, _ = PLAN(avail, internal, jnp.asarray([0], jnp.int32), jnp.int32(0))
    assert np.all(np.asarray(assign) == -1)


def test_saturated_dc():
    avail = jnp.zeros((model.P, model.W), dtype=jnp.float32)
    internal = jnp.ones(model.P, dtype=jnp.float32)
    assign, free = PLAN(avail, internal, jnp.asarray([0], jnp.int32), jnp.int32(64))
    assert np.all(np.asarray(assign) == -1)
    assert np.all(np.asarray(free) == 0.0)
