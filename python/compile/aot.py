"""AOT lowering: jax (L2+L1) -> HLO *text* artifacts for the Rust runtime.

HLO text — NOT ``lowered.compiler_ir(...).serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Writes:
  match_plan.hlo.txt   — plan_batch(avail f32[P,W], internal f32[P],
                          rr i32[1], n_tasks i32[]) -> (assign i32[T], free f32[P])
  delay_stats.hlo.txt  — delay_summary(delays f32[N], mask f32[N],
                          edges f32[B]) -> (cdf f32[B], moments f32[4])
  manifest.json        — shapes, for the Rust loader's sanity checks.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_match_plan() -> str:
    spec = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)
    lowered = jax.jit(model.plan_batch).lower(
        spec((model.P, model.W), jnp.float32),
        spec((model.P,), jnp.float32),
        spec((1,), jnp.int32),
        spec((), jnp.int32),
    )
    return to_hlo_text(lowered)


def lower_delay_stats() -> str:
    spec = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)
    lowered = jax.jit(model.delay_summary).lower(
        spec((model.N,), jnp.float32),
        spec((model.N,), jnp.float32),
        spec((model.B,), jnp.float32),
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, fn in [("match_plan", lower_match_plan), ("delay_stats", lower_delay_stats)]:
        text = fn()
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")

    manifest = {
        "match_plan": {
            "inputs": [
                {"name": "avail", "shape": [model.P, model.W], "dtype": "f32"},
                {"name": "internal", "shape": [model.P], "dtype": "f32"},
                {"name": "rr", "shape": [1], "dtype": "i32"},
                {"name": "n_tasks", "shape": [], "dtype": "i32"},
            ],
            "outputs": [
                {"name": "assign", "shape": [model.T], "dtype": "i32"},
                {"name": "free", "shape": [model.P], "dtype": "f32"},
            ],
        },
        "delay_stats": {
            "inputs": [
                {"name": "delays", "shape": [model.N], "dtype": "f32"},
                {"name": "mask", "shape": [model.N], "dtype": "f32"},
                {"name": "edges", "shape": [model.B], "dtype": "f32"},
            ],
            "outputs": [
                {"name": "cdf", "shape": [model.B], "dtype": "f32"},
                {"name": "moments", "shape": [4], "dtype": "f32"},
            ],
        },
        "consts": {"P": model.P, "W": model.W, "T": model.T, "N": model.N, "B": model.B},
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
