"""L2: the GM's batched placement planner as a jax computation.

``plan_batch`` is the operation a Megha GM runs once per job (paper
section 3.4.1): scan the eventually-consistent global state for free
workers, order partitions internal-first / round-robin, and allocate the
job's tasks greedily, saturating one partition before moving to the next.
The partition scan is the L1 Pallas kernel; the allocation is a
sort + cumsum + searchsorted pipeline that XLA fuses well.

``delay_summary`` wraps the stats kernel for the metrics pipeline.

Both are lowered ONCE by aot.py to HLO text; Rust loads them via PJRT and
calls them from the L3 hot path (rust/src/runtime/). Python never runs at
request time.
"""

import jax.numpy as jnp

from compile.kernels.match_kernel import match_score
from compile.kernels.stats_kernel import delay_stats

# AOT shapes (fixed at lowering; Rust pads to these).
P = 1024  # partitions
W = 64  # workers per partition
T = 512  # max tasks planned per call
N = 4096  # max delay samples per summary call
B = 64  # CDF bin edges


def plan_batch(avail, internal, rr, n_tasks):
    """Plan up to ``n_tasks`` task placements against the global state.

    Args:
      avail:    f32[P, W] availability bitmap (1.0 = free).
      internal: f32[P] internal-partition mask for the calling GM.
      rr:       i32[1] round-robin cursor.
      n_tasks:  i32[] number of tasks actually requested (<= T).

    Returns:
      assign: i32[T] partition index per task slot, -1 for unassigned
              (slot >= n_tasks or DC capacity exhausted).
      free:   f32[P] free-worker count per partition (for state refresh).
    """
    n_part = avail.shape[0]
    free, key = match_score(avail, internal, rr)
    order = jnp.argsort(-key, stable=True)
    cap = jnp.where(key[order] > 0.0, free[order], 0.0)
    cum = jnp.cumsum(cap)
    t = jnp.arange(T, dtype=jnp.float32)
    pos = jnp.searchsorted(cum, t, side="right")
    total = cum[-1]
    valid = t < jnp.minimum(n_tasks.astype(jnp.float32), total)
    assign = jnp.where(valid, order[jnp.clip(pos, 0, n_part - 1)], -1)
    return assign.astype(jnp.int32), free


def delay_summary(delays, mask, edges):
    """CDF counts + moments of a masked delay sample batch (see stats_kernel)."""
    return delay_stats(delays, mask, edges)
