"""L1 Pallas kernel: delay-distribution summary (CDF counts + moments).

The metrics pipeline (Figs. 2-4) summarises tens of thousands of per-job
delay samples into a CDF over fixed bin edges plus first moments. The
kernel streams N-blocks of samples and accumulates:

* ``cdf[b]``   = #samples <= edges[b]   (masked),
* ``moments``  = [count, sum, sum_sq, max].

The comparison matrix ``(d[:, None] <= e[None, :])`` reduced over N is a
``[Nb, B]`` reduction — again dot-shaped for the MXU. interpret=True, as
everywhere (see match_kernel.py).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_DEFAULT = 4096
B_DEFAULT = 64
BLOCK_N = 512


def _stats_block(d_ref, m_ref, e_ref, cdf_ref, mom_ref, *, block_n):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        cdf_ref[...] = jnp.zeros_like(cdf_ref)
        # max (slot 3) starts at -inf so an all-masked input reports -inf
        # (built via iota: pallas kernels cannot capture array constants)
        slot = jax.lax.iota(jnp.int32, 4)
        mom_ref[...] = jnp.where(slot == 3, -jnp.inf, 0.0).astype(jnp.float32)

    d = d_ref[...]  # [block_n]
    m = m_ref[...]  # [block_n] mask, 1.0 = valid sample
    e = e_ref[...]  # [B]
    le = (d[:, None] <= e[None, :]).astype(jnp.float32) * m[:, None]
    cdf_ref[...] += jnp.sum(le, axis=0)
    cnt = jnp.sum(m)
    s = jnp.sum(d * m)
    s2 = jnp.sum(d * d * m)
    # masked max: invalid samples contribute -inf
    mx = jnp.max(jnp.where(m > 0.0, d, -jnp.inf))
    prev = mom_ref[...]
    mom_ref[...] = jnp.stack(
        [prev[0] + cnt, prev[1] + s, prev[2] + s2, jnp.maximum(prev[3], mx)]
    )


def delay_stats(delays, mask, edges, *, block_n=BLOCK_N):
    """Pallas-backed delay-distribution summary.

    Args:
      delays: f32[N] delay samples (padded entries arbitrary).
      mask:   f32[N] 1.0 for valid samples, 0.0 for padding.
      edges:  f32[B] ascending CDF bin edges.

    Returns:
      (cdf, moments): f32[B] counts of samples <= edge, and
      f32[4] = [count, sum, sum_sq, max] (max = -inf when count == 0).
    """
    n, b = delays.shape[0], edges.shape[0]
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    kernel = partial(_stats_block, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        ],
        interpret=True,
    )(delays, mask, edges)
