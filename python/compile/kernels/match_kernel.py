"""L1 Pallas kernel: the GM's placement-match hot-spot.

Given the GM's eventually-consistent global availability state — a `[P, W]`
bitmap of P partitions x W workers (1.0 = free) — compute, per partition:

* ``free[p]``  — number of free workers (a ``[P,W] @ [W,1]`` dot, so the
  reduction is MXU-shaped on real TPU hardware), and
* ``key[p]``   — the partition-ordering key used by the GM's round-robin,
  internal-first search (paper section 3.2/3.4.1):

  - partitions with no free workers sort last (key 0),
  - *internal* partitions (owned by this GM) with free workers sort first,
  - within each class, partitions are visited round-robin starting at the
    GM's rotation cursor ``rr``.

  key[p] = has_free[p] * (internal[p] * P + (P - rot[p])),
  rot[p] = (p - rr) mod P

  giving disjoint ranges (P, 2P] for internal-free, (0, P] for
  external-free and {0} for saturated partitions, so a descending sort of
  ``key`` yields exactly the paper's search order.

The kernel is lowered with ``interpret=True`` (CPU-PJRT cannot run Mosaic
custom-calls); see DESIGN.md section Hardware-Adaptation for the TPU tiling
rationale (P-blocked BlockSpec, bitmap resident in VMEM).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT shapes (padded): 1024 partitions x 64 workers = 64 Ki workers.
P_DEFAULT = 1024
W_DEFAULT = 64
BLOCK_P = 128


def _match_block(avail_ref, internal_ref, rr_ref, free_ref, key_ref, *, block_p, n_part):
    """One P-block: free counts via dot, ordering key elementwise."""
    a = avail_ref[...]  # [block_p, W]
    ones = jnp.ones((a.shape[1], 1), dtype=a.dtype)
    free = jnp.dot(a, ones)[:, 0]  # [block_p] -- MXU-shaped reduction
    pid = pl.program_id(0)
    idx = pid * block_p + jax.lax.iota(jnp.int32, block_p)
    rr = rr_ref[0]
    rot = jnp.mod(idx - rr, n_part).astype(jnp.float32)
    internal = internal_ref[...]
    has_free = (free > 0.0).astype(jnp.float32)
    npf = jnp.float32(n_part)
    key = has_free * (internal * npf + (npf - rot))
    free_ref[...] = free
    key_ref[...] = key


def match_score(avail, internal, rr, *, block_p=BLOCK_P):
    """Pallas-backed match operation.

    Args:
      avail:    f32[P, W] availability bitmap (1.0 = free).
      internal: f32[P] 1.0 where the partition is internal to this GM.
      rr:       i32[1] round-robin rotation cursor (partition index).

    Returns:
      (free, key): f32[P] free-worker counts and f32[P] ordering keys.
    """
    n_part, n_work = avail.shape
    block_p = min(block_p, n_part)
    assert n_part % block_p == 0, (n_part, block_p)
    grid = (n_part // block_p,)
    kernel = partial(_match_block, block_p=block_p, n_part=n_part)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, n_work), lambda i: (i, 0)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_part,), jnp.float32),
            jax.ShapeDtypeStruct((n_part,), jnp.float32),
        ],
        interpret=True,
    )(avail, internal, rr)
