"""Pure-jnp oracles for the Pallas kernels (correctness ground truth).

These are deliberately written in the most obvious vectorised style; the
pytest suite asserts the Pallas kernels match them exactly (same float32
arithmetic, so tolerances are tight).
"""

import jax.numpy as jnp


def match_score_ref(avail, internal, rr):
    """Reference for match_kernel.match_score. Same contract."""
    n_part = avail.shape[0]
    free = jnp.sum(avail, axis=1)
    idx = jnp.arange(n_part, dtype=jnp.int32)
    rot = jnp.mod(idx - rr[0], n_part).astype(jnp.float32)
    has_free = (free > 0.0).astype(jnp.float32)
    npf = jnp.float32(n_part)
    key = has_free * (internal * npf + (npf - rot))
    return free, key


def delay_stats_ref(delays, mask, edges):
    """Reference for stats_kernel.delay_stats. Same contract."""
    le = (delays[:, None] <= edges[None, :]).astype(jnp.float32) * mask[:, None]
    cdf = jnp.sum(le, axis=0)
    cnt = jnp.sum(mask)
    s = jnp.sum(delays * mask)
    s2 = jnp.sum(delays * delays * mask)
    mx = jnp.max(jnp.where(mask > 0.0, delays, -jnp.inf))
    return cdf, jnp.stack([cnt, s, s2, mx])


def plan_batch_ref(avail, internal, rr, n_tasks, n_slots):
    """Reference task->partition plan (mirrors model.plan_batch).

    Greedy fill in the paper's search order: internal partitions first,
    round-robin from ``rr``, saturating each partition before moving on.
    Returns (assign i32[n_slots] with -1 padding, free f32[P]).
    """
    n_part = avail.shape[0]
    free, key = match_score_ref(avail, internal, rr)
    order = jnp.argsort(-key, stable=True)
    cap = jnp.where(key[order] > 0.0, free[order], 0.0)
    cum = jnp.cumsum(cap)
    t = jnp.arange(n_slots, dtype=jnp.float32)
    pos = jnp.searchsorted(cum, t, side="right")
    total = cum[-1]
    valid = t < jnp.minimum(jnp.float32(n_tasks), total)
    assign = jnp.where(valid, order[jnp.clip(pos, 0, n_part - 1)], -1)
    return assign.astype(jnp.int32), free
