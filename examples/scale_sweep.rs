//! Fig. 2 reproduction: sweep DC size (10k–50k workers) and offered load,
//! reporting Megha's 95th-percentile job delay and inconsistency ratio.
//!
//! ```sh
//! cargo run --release --example scale_sweep -- --scale default
//! ```

use megha::experiments::{fig2, Scale};
use megha::util::args::Args;

fn main() {
    let args = Args::from_env(&[]);
    let scale = Scale::parse(&args.get_or("scale", "default")).expect("bad --scale");
    let rows = fig2::run(scale, args.u64("seed", 0));

    // paper shape check: within each DC size, delay and inconsistencies
    // must rise as load approaches 1
    let mut shape_ok = true;
    for w in rows.iter().map(|r| r.workers).collect::<std::collections::BTreeSet<_>>() {
        let mut per: Vec<_> = rows.iter().filter(|r| r.workers == w).collect();
        per.sort_by(|a, b| a.load.partial_cmp(&b.load).unwrap());
        if per.len() >= 2 {
            let first = per.first().unwrap();
            let last = per.last().unwrap();
            if last.inconsistency_ratio < first.inconsistency_ratio {
                shape_ok = false;
            }
        }
    }
    println!(
        "\nverdict: inconsistencies rise with load {}",
        if shape_ok { "✔ (paper shape holds)" } else { "✘" }
    );
}
