//! Profiling driver: event-throughput measurement for the Megha engine.
use std::time::Instant;

fn main() {
    let mut cfg = megha::config::MeghaConfig::for_workers(3_000);
    cfg.sim.seed = 1;
    let trace = megha::workload::synthetic::yahoo_like(300, 3_000, 0.85, 3);
    let n_tasks = trace.n_tasks();
    // warmup
    let out = megha::sched::megha::simulate(&cfg, &trace);
    let msgs = out.messages;
    let t0 = Instant::now();
    let reps = 20;
    for _ in 0..reps {
        let out = megha::sched::megha::simulate(&cfg, &trace);
        std::hint::black_box(out.decisions);
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "yahoo300: {:.1} ms/run, {:.0} tasks/s, {:.0} msgs/s ({} tasks, {} msgs)",
        dt * 1e3,
        n_tasks as f64 / dt,
        msgs as f64 / dt,
        n_tasks,
        msgs
    );
}
