//! End-to-end driver (DESIGN.md E3/P1): run all four scheduling
//! architectures — Megha, Sparrow, Eagle, Pigeon — on real (synthesized
//! to published marginals) Yahoo-like and Google-like traces, and report
//! the paper's headline metric: delay in job completion time, plus the
//! mean-delay reduction factors of §5.2.
//!
//! ```sh
//! cargo run --release --example compare_frameworks            # default scale
//! cargo run --release --example compare_frameworks -- --scale smoke
//! ```

use megha::experiments::{fig3, headline, Scale};
use megha::util::args::Args;

fn main() {
    let args = Args::from_env(&[]);
    let scale = Scale::parse(&args.get_or("scale", "default")).expect("bad --scale");
    let seed = args.u64("seed", 0);

    fig3::run(fig3::Workload::Yahoo, scale, seed);
    fig3::run(fig3::Workload::Google, scale, seed);
    let rows = headline::run(scale, seed);

    // sanity verdict against the paper's shape
    let ok = rows.iter().all(|r| r.vs_sparrow > 1.0);
    println!(
        "\nverdict: megha beats sparrow on mean delay in {}/{} workloads {}",
        rows.iter().filter(|r| r.vs_sparrow > 1.0).count(),
        rows.len(),
        if ok { "✔ (paper shape holds)" } else { "✘" }
    );
}
