//! Quickstart: simulate Megha on a small synthetic workload and print
//! the paper's core metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use megha::config::MeghaConfig;
use megha::metrics::summarize_jobs;
use megha::sched::megha::simulate;
use megha::workload::synthetic::synthetic_fixed;

fn main() {
    // a 1 000-worker DC at 70% offered load
    let mut cfg = MeghaConfig::for_workers(1_000);
    cfg.sim.seed = 42;
    println!(
        "topology: {} GMs x {} LMs x {} workers/partition = {} workers",
        cfg.spec.n_gm,
        cfg.spec.n_lm,
        cfg.spec.workers_per_partition,
        cfg.spec.n_workers()
    );

    let trace = synthetic_fixed(100, 200, 1.0, 0.7, cfg.spec.n_workers(), 7);
    println!(
        "workload: {} jobs / {} tasks, offered load {:.2}",
        trace.n_jobs(),
        trace.n_tasks(),
        trace.offered_load(cfg.spec.n_workers())
    );

    let out = simulate(&cfg, &trace);
    let s = summarize_jobs(&out.jobs);
    println!("\nresults:");
    println!("  delay in JCT: median {:.4}s  p95 {:.4}s  max {:.4}s", s.median, s.p95, s.max);
    println!(
        "  inconsistencies: {} over {} tasks ({:.5}/task)",
        out.inconsistencies,
        out.tasks,
        out.inconsistency_ratio()
    );
    println!("  messages {}  scheduling decisions {}  sdps {:.0}", out.messages, out.decisions, out.sdps());
    println!("\n(see `megha experiment all` for the full paper reproduction)");
}
