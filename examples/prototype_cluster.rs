//! Fig. 4 reproduction on the real TCP deployment: Megha GMs + LM
//! services vs the Pigeon distributor + coordinator services, replaying
//! the down-sampled traces in scaled wall-clock time. When `make
//! artifacts` has been run, the Megha GM's match operation executes the
//! AOT-compiled XLA artifact (L1 Pallas kernel + L2 plan) via PJRT —
//! python is never on the request path.
//!
//! ```sh
//! cargo run --release --example prototype_cluster -- --scale smoke
//! cargo run --release --example prototype_cluster -- --xla   # PJRT match engine
//! ```

use megha::experiments::{fig4, Scale};
use megha::runtime::pjrt::artifacts_available;
use megha::util::args::Args;

fn main() {
    let args = Args::from_env(&["xla"]);
    let scale = Scale::parse(&args.get_or("scale", "smoke")).expect("bad --scale");
    let seed = args.u64("seed", 0);

    if args.flag("xla") && !artifacts_available() {
        eprintln!("--xla requested but artifacts/ missing; run `make artifacts`");
        std::process::exit(1);
    }

    let a = fig4::run(fig4::Workload::Yahoo, scale, seed).expect("fig4a run");
    let b = fig4::run(fig4::Workload::Google, scale, seed).expect("fig4b run");

    let verdict = |rows: &[fig4::Fig4Row]| {
        let megha = rows.iter().find(|r| r.framework == "megha").unwrap();
        let pigeon = rows.iter().find(|r| r.framework == "pigeon").unwrap();
        megha.summary.p95 <= pigeon.summary.p95
    };
    println!(
        "\nverdict: megha p95 <= pigeon p95 on yahoo: {} — google: {}",
        if verdict(&a) { "✔" } else { "✘" },
        if verdict(&b) { "✔" } else { "✘" }
    );
}
