fn main() {
    let mut cfg = megha::config::MeghaConfig::for_workers(3_000);
    cfg.sim.seed = 1;
    let trace = megha::workload::synthetic::yahoo_like(300, 3_000, 0.85, 3);
    let out = megha::sched::megha::simulate(&cfg, &trace);
    println!("makespan {:.0}s inconsistencies {} msgs {} tasks {} decisions {}",
        out.makespan.as_secs(), out.inconsistencies, out.messages, out.tasks, out.decisions);
    println!("applies {} skips {}",
        megha::sched::megha::engine::APPLY_TOTAL.load(std::sync::atomic::Ordering::Relaxed),
        megha::sched::megha::engine::APPLY_SKIP.load(std::sync::atomic::Ordering::Relaxed));
}
// (instrumentation printout appended by perf pass)
